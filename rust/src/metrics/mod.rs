//! Estimation-accuracy metrics (paper §7, eqs. 14–18), summary statistics
//! for the figures, and process-wide operational [`counters`] fed by the
//! unified estimation engine.

/// Process-wide monotonic counters for the estimation hot path. Every
/// [`EstimationEngine`](crate::engine::EstimationEngine) — the global one
/// *and* any locally constructed one (e.g. a bench comparison's private
/// engine) — reports here, so unlike the global engine's own stats these
/// are whole-process telemetry. The serve loop's `stats` command prints
/// them via [`snapshot`] alongside the global engine's cache state.
pub mod counters {
    use std::sync::atomic::{AtomicU64, Ordering};

    /// A named monotonic counter.
    pub struct Counter {
        name: &'static str,
        value: AtomicU64,
    }

    impl Counter {
        const fn new(name: &'static str) -> Self {
            Self { name, value: AtomicU64::new(0) }
        }

        /// Add `n` to the counter.
        pub fn add(&self, n: u64) {
            self.value.fetch_add(n, Ordering::Relaxed);
        }

        /// Current value.
        pub fn get(&self) -> u64 {
            self.value.load(Ordering::Relaxed)
        }

        /// The counter's registered name.
        pub fn name(&self) -> &'static str {
            self.name
        }
    }

    /// Network estimates served by any engine.
    pub static ENGINE_REQUESTS: Counter = Counter::new("engine.requests");
    /// Kernel slots seen (every kernel of every non-fused layer).
    pub static ENGINE_KERNELS_TOTAL: Counter = Counter::new("engine.kernels.total");
    /// Kernels actually evaluated through the AIDG.
    pub static ENGINE_KERNELS_EVALUATED: Counter = Counter::new("engine.kernels.evaluated");
    /// Kernel slots served from an estimate cache.
    pub static ENGINE_CACHE_HITS: Counter = Counter::new("engine.cache.hits");
    /// Kernel slots deduplicated within a single request.
    pub static ENGINE_KERNELS_DEDUPED: Counter = Counter::new("engine.kernels.deduped");
    /// DSE design points enumerated (including unmappable ones).
    pub static DSE_POINTS_ENUMERATED: Counter = Counter::new("dse.points.enumerated");
    /// DSE design points that reached the roofline pre-filter (mappable
    /// candidates; enumerated minus degenerate skips).
    pub static DSE_POINTS_PREFILTERED: Counter = Counter::new("dse.points.prefiltered");
    /// DSE design points that survived into the accurate AIDG pass.
    pub static DSE_POINTS_ESTIMATED: Counter = Counter::new("dse.points.estimated");
    /// AIDG nodes processed by any evaluator (the §6.2 work unit — the
    /// denominator of the evaluator-throughput numbers in
    /// `BENCH_eval.json`).
    pub static AIDG_NODES: Counter = Counter::new("aidg.nodes");
    /// Loop-kernel iterations evaluated by any evaluator.
    pub static AIDG_ITERATIONS: Counter = Counter::new("aidg.iterations");
    /// Digest-group batches driven by the lane-batched evaluator.
    pub static AIDG_BATCH_GROUPS: Counter = Counter::new("aidg.batch.groups");
    /// Lanes submitted to the lane-batched evaluator (avg lanes per batch =
    /// `aidg.batch.lanes / aidg.batch.groups`).
    pub static AIDG_BATCH_LANES: Counter = Counter::new("aidg.batch.lanes");
    /// Lanes evicted from a batch to the serial path (divergence:
    /// digest/route/partition mismatch).
    pub static AIDG_BATCH_EVICTIONS: Counter = Counter::new("aidg.batch.evictions");
    /// Instructions executed through the fused threaded tape.
    pub static AIDG_DISPATCH_THREADED: Counter = Counter::new("aidg.dispatch.threaded");
    /// Instructions an evaluator in threaded mode routed to the node-table
    /// walk instead (non-fusible offsets + run-time guard failures).
    pub static AIDG_DISPATCH_FALLBACK: Counter = Counter::new("aidg.dispatch.fallback");
    /// Superinstruction ops executed on the threaded tape (fusion quality:
    /// compare against `aidg.nodes`).
    pub static AIDG_FUSED_OPS: Counter = Counter::new("aidg.fused.ops");
    /// Dynamic-latency memo hits on the threaded tape.
    pub static AIDG_DYN_MEMO_HITS: Counter = Counter::new("aidg.dyn_memo.hits");
    /// Dynamic-latency memo misses (cold fills + long-tuple bypasses).
    pub static AIDG_DYN_MEMO_MISSES: Counter = Counter::new("aidg.dyn_memo.misses");
    /// Paired (AIDG, DES) observations consumed by calibration training.
    pub static CALIB_SAMPLES: Counter = Counter::new("calib.samples");
    /// Layer estimates stamped with calibrated cycles + CI bounds.
    pub static CALIB_LAYERS: Counter = Counter::new("calib.layers");
    /// Persistent-store lookups that found a record on disk.
    pub static STORE_HITS: Counter = Counter::new("store.hits");
    /// Persistent-store lookups that missed.
    pub static STORE_MISSES: Counter = Counter::new("store.misses");
    /// New records accepted by the persistent store (pending until flush).
    pub static STORE_WRITES: Counter = Counter::new("store.writes");
    /// Records dropped by `store gc` as unreferenced this generation.
    pub static STORE_GC_DROPPED: Counter = Counter::new("store.gc_dropped");
    /// Serve sessions accepted (stdio runs and TCP connections).
    pub static SERVE_SESSIONS: Counter = Counter::new("serve.sessions");
    /// TCP connections refused with a `busy` line at the client cap.
    pub static SERVE_BUSY_REJECTS: Counter = Counter::new("serve.busy_rejects");
    /// Requests that parked on another thread's in-flight evaluation of
    /// the same kernel instead of evaluating it themselves.
    pub static SERVE_INFLIGHT_WAITS: Counter = Counter::new("serve.inflight_waits");

    /// One layer estimation's evaluator accounting, in one call.
    pub fn note_aidg(nodes: u64, iterations: u64) {
        AIDG_NODES.add(nodes);
        AIDG_ITERATIONS.add(iterations);
    }

    /// One evaluator run's threaded-dispatch accounting, in one call
    /// (deltas — evaluators flush at the end of each `run`).
    pub fn note_dispatch(threaded: u64, fallback: u64, fused_ops: u64, hits: u64, misses: u64) {
        AIDG_DISPATCH_THREADED.add(threaded);
        AIDG_DISPATCH_FALLBACK.add(fallback);
        AIDG_FUSED_OPS.add(fused_ops);
        AIDG_DYN_MEMO_HITS.add(hits);
        AIDG_DYN_MEMO_MISSES.add(misses);
    }

    /// One kernel batch's accounting, in one call (the request counter is
    /// bumped separately — kernel-batch APIs are not whole requests).
    pub fn note_engine_kernels(kernels: u64, evaluated: u64, hits: u64, deduped: u64) {
        ENGINE_KERNELS_TOTAL.add(kernels);
        ENGINE_KERNELS_EVALUATED.add(evaluated);
        ENGINE_CACHE_HITS.add(hits);
        ENGINE_KERNELS_DEDUPED.add(deduped);
    }

    /// Snapshot of every counter, for reporting.
    pub fn snapshot() -> Vec<(&'static str, u64)> {
        [
            &ENGINE_REQUESTS,
            &ENGINE_KERNELS_TOTAL,
            &ENGINE_KERNELS_EVALUATED,
            &ENGINE_CACHE_HITS,
            &ENGINE_KERNELS_DEDUPED,
            &DSE_POINTS_ENUMERATED,
            &DSE_POINTS_PREFILTERED,
            &DSE_POINTS_ESTIMATED,
            &AIDG_NODES,
            &AIDG_ITERATIONS,
            &AIDG_BATCH_GROUPS,
            &AIDG_BATCH_LANES,
            &AIDG_BATCH_EVICTIONS,
            &AIDG_DISPATCH_THREADED,
            &AIDG_DISPATCH_FALLBACK,
            &AIDG_FUSED_OPS,
            &AIDG_DYN_MEMO_HITS,
            &AIDG_DYN_MEMO_MISSES,
            &CALIB_SAMPLES,
            &CALIB_LAYERS,
            &STORE_HITS,
            &STORE_MISSES,
            &STORE_WRITES,
            &STORE_GC_DROPPED,
            &SERVE_SESSIONS,
            &SERVE_BUSY_REJECTS,
            &SERVE_INFLIGHT_WAITS,
        ]
        .iter()
        .map(|c| (c.name(), c.get()))
        .collect()
    }
}

/// Percentage error of a whole-DNN estimate (eq. 15).
pub fn percentage_error(estimated: f64, measured: f64) -> f64 {
    if measured == 0.0 {
        return 0.0;
    }
    (estimated - measured) / measured * 100.0
}

/// Mean absolute percentage error over per-layer latencies (eq. 16).
/// Zero-valued measured entries (fused layers emit 0 in per-layer cycle
/// vectors) are skipped rather than dividing by zero; an empty or all-zero
/// input yields 0. Panics when the slices disagree in length — that is a
/// caller bug, not a data condition.
pub fn mape(measured: &[f64], estimated: &[f64]) -> f64 {
    assert_eq!(measured.len(), estimated.len());
    if measured.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    let mut n = 0usize;
    for (&m, &e) in measured.iter().zip(estimated) {
        if m != 0.0 {
            acc += ((m - e) / m).abs();
            n += 1;
        }
    }
    if n == 0 { 0.0 } else { acc / n as f64 * 100.0 }
}

/// Fraction of measured values inside their `[lo, hi]` interval (1.0 for
/// empty input — an empty claim set is vacuously covered). The calibration
/// accuracy gate requires ≥ 0.95 of held-out DES cycle counts inside the
/// reported confidence bounds.
pub fn coverage(measured: &[f64], lo: &[f64], hi: &[f64]) -> f64 {
    assert_eq!(measured.len(), lo.len());
    assert_eq!(measured.len(), hi.len());
    if measured.is_empty() {
        return 1.0;
    }
    let inside = measured
        .iter()
        .zip(lo.iter().zip(hi))
        .filter(|&(&m, (&l, &h))| l <= m && m <= h)
        .count();
    inside as f64 / measured.len() as f64
}

/// Sample variance (unbiased, n-1 denominator) — eqs. 17/18 operate on the
/// per-iteration Δt traces.
pub fn sample_variance(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
}

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Pearson correlation coefficient ρ (Table 7).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx2 = 0.0;
    let mut dy2 = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        num += dx * dy;
        dx2 += dx * dx;
        dy2 += dy * dy;
    }
    if dx2 == 0.0 || dy2 == 0.0 {
        return 0.0;
    }
    num / (dx2 * dy2).sqrt()
}

/// Five-number summary + outliers for the memory box plots (Figs. 11/12).
#[derive(Debug, Clone, PartialEq)]
pub struct BoxStats {
    /// Minimum.
    pub min: f64,
    /// First quartile.
    pub q1: f64,
    /// Median.
    pub median: f64,
    /// Third quartile.
    pub q3: f64,
    /// Maximum.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Points outside 1.5 × IQR whiskers.
    pub outliers: Vec<f64>,
}

/// Linear-interpolated quantile of a sorted slice.
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Five-number summary of `xs` (linear-interpolated quartiles).
pub fn box_stats(xs: &[f64]) -> BoxStats {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q1 = quantile(&sorted, 0.25);
    let q3 = quantile(&sorted, 0.75);
    let iqr = q3 - q1;
    let lo_w = q1 - 1.5 * iqr;
    let hi_w = q3 + 1.5 * iqr;
    let outliers: Vec<f64> =
        sorted.iter().copied().filter(|&x| x < lo_w || x > hi_w).collect();
    BoxStats {
        min: sorted.first().copied().unwrap_or(0.0),
        q1,
        median: quantile(&sorted, 0.5),
        q3,
        max: sorted.last().copied().unwrap_or(0.0),
        mean: mean(&sorted),
        outliers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pe_signs() {
        assert!((percentage_error(110.0, 100.0) - 10.0).abs() < 1e-12);
        assert!((percentage_error(90.0, 100.0) + 10.0).abs() < 1e-12);
        assert_eq!(percentage_error(1.0, 0.0), 0.0);
    }

    #[test]
    fn mape_basic() {
        let m = vec![100.0, 200.0];
        let e = vec![110.0, 180.0];
        assert!((mape(&m, &e) - 10.0).abs() < 1e-12);
        assert_eq!(mape(&[], &[]), 0.0);
        // exact estimates: zero error
        assert_eq!(mape(&m, &m.clone()), 0.0);
    }

    #[test]
    fn variance_matches_hand_calc() {
        let xs = vec![2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        // mean 5, sum sq dev 32, n-1 = 7
        assert!((sample_variance(&xs) - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(sample_variance(&[1.0]), 0.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        let ys = vec![2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[1.0, 1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn box_stats_detects_outliers() {
        let mut xs: Vec<f64> = (0..100).map(|i| 50.0 + (i % 10) as f64).collect();
        xs.push(1e6);
        let b = box_stats(&xs);
        assert_eq!(b.outliers, vec![1e6]);
        assert!(b.q1 <= b.median && b.median <= b.q3);
        assert_eq!(b.max, 1e6);
    }

    #[test]
    fn quantiles_interpolate() {
        let b = box_stats(&[1.0, 2.0, 3.0, 4.0]);
        assert!((b.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn counters_accumulate_and_snapshot() {
        // counters are process-global; assert deltas, not absolutes
        let before = counters::ENGINE_KERNELS_TOTAL.get();
        counters::note_engine_kernels(10, 4, 5, 1);
        counters::ENGINE_REQUESTS.add(1);
        assert_eq!(counters::ENGINE_KERNELS_TOTAL.get(), before + 10);
        let snap = counters::snapshot();
        assert_eq!(snap.len(), 27);
        assert!(snap.iter().any(|(n, _)| *n == "engine.kernels.total"));
        assert!(snap.iter().any(|(n, _)| *n == "aidg.batch.lanes"));
        assert!(snap.iter().any(|(n, _)| *n == "aidg.dispatch.threaded"));
        assert!(snap.iter().any(|(n, _)| *n == "aidg.dispatch.fallback"));
        assert!(snap.iter().any(|(n, _)| *n == "aidg.fused.ops"));
        assert!(snap.iter().any(|(n, _)| *n == "aidg.dyn_memo.hits"));
        assert!(snap.iter().any(|(n, _)| *n == "aidg.dyn_memo.misses"));
        assert!(snap.iter().any(|(n, _)| *n == "dse.points.enumerated"));
        assert!(snap.iter().any(|(n, _)| *n == "dse.points.prefiltered"));
        assert!(snap.iter().any(|(n, _)| *n == "dse.points.estimated"));
        assert!(snap.iter().any(|(n, _)| *n == "calib.samples"));
        assert!(snap.iter().any(|(n, _)| *n == "calib.layers"));
        assert!(snap.iter().any(|(n, _)| *n == "store.hits"));
        assert!(snap.iter().any(|(n, _)| *n == "store.misses"));
        assert!(snap.iter().any(|(n, _)| *n == "store.writes"));
        assert!(snap.iter().any(|(n, _)| *n == "store.gc_dropped"));
        assert!(snap.iter().any(|(n, _)| *n == "serve.sessions"));
        assert!(snap.iter().any(|(n, _)| *n == "serve.busy_rejects"));
        assert!(snap.iter().any(|(n, _)| *n == "serve.inflight_waits"));
    }

    #[test]
    fn mape_skips_zero_measured_entries() {
        // fused layers report 0 measured cycles; they must not divide by
        // zero or drag the mean toward infinity
        let m = vec![0.0, 100.0, 0.0, 200.0];
        let e = vec![50.0, 110.0, 7.0, 180.0];
        assert!((mape(&m, &e) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn mape_all_zero_measured_is_zero() {
        assert_eq!(mape(&[0.0, 0.0], &[3.0, 4.0]), 0.0);
    }

    #[test]
    #[should_panic]
    fn mape_rejects_mismatched_lengths() {
        mape(&[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn coverage_counts_inclusive_bounds() {
        let m = vec![10.0, 20.0, 30.0, 40.0];
        let lo = vec![10.0, 25.0, 29.0, 0.0];
        let hi = vec![10.0, 30.0, 31.0, 39.0];
        // 10 in [10,10], 20 below [25,30], 30 in [29,31], 40 above [0,39]
        assert!((coverage(&m, &lo, &hi) - 0.5).abs() < 1e-12);
        assert_eq!(coverage(&[], &[], &[]), 1.0);
    }

    #[test]
    #[should_panic]
    fn coverage_rejects_mismatched_lengths() {
        coverage(&[1.0], &[0.0, 0.0], &[2.0, 2.0]);
    }

    #[test]
    fn counter_names_follow_the_dotted_convention() {
        for (name, _) in counters::snapshot() {
            assert!(
                name.contains('.'),
                "counter {name:?} must use the dotted naming convention (e.g. engine.requests)"
            );
            assert!(
                !name.contains(' ') && !name.contains('='),
                "counter {name:?} must be machine-line safe: dot-separated lowercase segments"
            );
            assert!(
                name.split('.').all(|seg| {
                    !seg.is_empty()
                        && !seg.starts_with('_')
                        && !seg.ends_with('_')
                        && seg
                            .chars()
                            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
                }),
                "counter {name:?} has an empty or non-lowercase dotted segment \
                 (underscores may join words *within* a segment, e.g. dyn_memo)"
            );
        }
    }
}
