//! Request-loop front-end: parses architecture specs and serves estimation
//! requests line-by-line (the `acadl-perf serve` mode and the CLI's shared
//! argument grammar).
//!
//! Architecture spec grammar:
//!
//! ```text
//! systolic:<rows>x<cols>[:pw<port_width>]
//! ultratrail[:<dim>]
//! gemmini[:<dim>]
//! plasticine:<rows>x<cols>:<tile>
//! ```

use std::io::{BufRead, Write};

use anyhow::{bail, Context};

use crate::accel::{GemminiConfig, PlasticineConfig, SystolicConfig, UltraTrailConfig};
use crate::aidg::FixedPointConfig;
use crate::Result;

use super::job::{run_request, Arch, EstimateRequest};

/// Parse an architecture spec string.
pub fn parse_arch(spec: &str) -> Result<Arch> {
    let parts: Vec<&str> = spec.split(':').collect();
    match parts[0] {
        "systolic" => {
            let dims = parts.get(1).context("systolic needs <rows>x<cols>")?;
            let (r, c) = parse_dims(dims)?;
            let mut cfg = SystolicConfig::new(r, c);
            if let Some(pw) = parts.get(2) {
                let pw = pw
                    .strip_prefix("pw")
                    .context("third field must be pw<N>")?
                    .parse::<u32>()?;
                cfg = cfg.with_port_width(pw);
            }
            Ok(Arch::Systolic(cfg))
        }
        "ultratrail" => {
            let mut cfg = UltraTrailConfig::default();
            if let Some(d) = parts.get(1) {
                cfg.array_dim = d.parse()?;
            }
            Ok(Arch::UltraTrail(cfg))
        }
        "gemmini" => {
            let mut cfg = GemminiConfig::default();
            if let Some(d) = parts.get(1) {
                cfg.dim = d.parse()?;
            }
            Ok(Arch::Gemmini(cfg))
        }
        "plasticine" => {
            let dims = parts.get(1).context("plasticine needs <rows>x<cols>:<tile>")?;
            let (r, c) = parse_dims(dims)?;
            let tile = parts.get(2).context("plasticine needs a tile size")?.parse()?;
            Ok(Arch::Plasticine(PlasticineConfig::new(r, c, tile)))
        }
        other => bail!("unknown architecture {other:?} (systolic|ultratrail|gemmini|plasticine)"),
    }
}

fn parse_dims(s: &str) -> Result<(u32, u32)> {
    let (r, c) = s.split_once('x').context("expected <rows>x<cols>")?;
    Ok((r.parse()?, c.parse()?))
}

/// Serve `estimate <arch> <network>` requests from `input`, writing one
/// result line per request to `output`. Returns the number served.
pub fn serve(input: impl BufRead, mut output: impl Write) -> Result<usize> {
    let mut served = 0;
    for line in input.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "quit" {
            break;
        }
        match serve_line(line) {
            Ok(msg) => writeln!(output, "{msg}")?,
            Err(e) => writeln!(output, "error: {e:#}")?,
        }
        served += 1;
    }
    Ok(served)
}

fn serve_line(line: &str) -> Result<String> {
    let mut it = line.split_whitespace();
    match it.next() {
        Some("estimate") => {
            let arch = parse_arch(it.next().context("estimate <arch> <network>")?)?;
            let network = it.next().context("estimate <arch> <network>")?.to_string();
            let e = run_request(&EstimateRequest { arch, network, fp: FixedPointConfig::default() })?;
            Ok(format!(
                "{} {} cycles={} evaluated_iters={} total_iters={} runtime_ms={}",
                e.arch,
                e.network,
                e.total_cycles(),
                e.evaluated_iters(),
                e.total_iters(),
                e.runtime.as_millis()
            ))
        }
        Some(cmd) => bail!("unknown command {cmd:?} (estimate|quit)"),
        None => bail!("empty command"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_arch_specs() {
        assert!(matches!(parse_arch("systolic:4x4").unwrap(), Arch::Systolic(c) if c.rows == 4));
        let pw = parse_arch("systolic:12x12:pw7").unwrap();
        assert!(matches!(pw, Arch::Systolic(c) if c.port_width == 7));
        assert!(matches!(parse_arch("ultratrail").unwrap(), Arch::UltraTrail(c) if c.array_dim == 8));
        assert!(matches!(parse_arch("gemmini:32").unwrap(), Arch::Gemmini(c) if c.dim == 32));
        assert!(
            matches!(parse_arch("plasticine:3x6:16").unwrap(), Arch::Plasticine(c) if c.tile == 16)
        );
        assert!(parse_arch("tpu").is_err());
        assert!(parse_arch("systolic").is_err());
        assert!(parse_arch("plasticine:3x6").is_err());
    }

    #[test]
    fn serve_estimates_and_reports_errors() {
        let input = "# comment\nestimate ultratrail tc_resnet8\nestimate ultratrail alexnet\nbogus\nquit\n";
        let mut out = Vec::new();
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        assert_eq!(served, 3);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].contains("cycles="), "{}", lines[0]);
        assert!(lines[1].starts_with("error:"));
        assert!(lines[2].starts_with("error:"));
    }
}
