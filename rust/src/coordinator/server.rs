//! Request-loop front-end: parses architecture specs and serves estimation
//! requests line-by-line (the `acadl-perf serve` mode and the CLI's shared
//! argument grammar).
//!
//! Architecture spec grammar:
//!
//! ```text
//! systolic:<rows>x<cols>[:pw<port_width>]
//! ultratrail[:<dim>]
//! gemmini[:<dim>]
//! plasticine:<rows>x<cols>:<tile>
//! file:<path>                    textual ACADL description file
//! @<name>                        inline description registered via `describe`
//! ```
//!
//! Network spec grammar:
//!
//! ```text
//! <zoo name>                     tc_resnet8 | alexnet | ... (`acadl-perf info`)
//! net:<path>                     textual network description file (net/*.toml)
//! @<name>                        inline description registered via
//!                                `network describe`
//! ```
//!
//! Server protocol (one command per line; see `docs/serve-protocol.md`):
//!
//! ```text
//! estimate <arch> <network>      run one estimate, print one result line
//! describe <name>                read architecture description lines until
//!                                `end`, then register it as `@<name>`
//! network describe <name>        read network description lines until
//!                                `end`, then register it as `@<name>`
//! sweep <arch> <network> [keep=F] [cap=N]
//!                                explore the architecture's [sweep] space
//!                                (file:<path> or @described), one summary
//!                                line
//! frontier                       the last sweep's Pareto frontier: one
//!                                header line, then one `point` line each
//! calibrate <file>|off           install a persisted calibration model
//!                                (estimates gain calibrated=/ci_lo=/ci_hi=
//!                                tokens) or remove it
//! store stats|flush|gc           persistent estimate store: one stats
//!                                line, flush pending records, or drop
//!                                unreferenced entries (needs --store)
//! stats                          engine cache/dedup + dse counters, one
//!                                line
//! metrics                        full telemetry snapshot: counters, pool/
//!                                cache gauges, per-span latency summaries,
//!                                one machine-readable line
//! trace on|off                   toggle span tracing for this process
//! shutdown                       stop serving; over TCP, also drain and
//!                                stop the whole listener
//! quit                           stop serving (this session only)
//! ```
//!
//! The same protocol runs per-connection over TCP (`serve --listen`, see
//! [`super::net`]): sessions are isolated (inline descriptions, last
//! sweep) but share the global engine, cache, store, and worker pool.
//!
//! Estimates run through the global
//! [`EstimationEngine`](crate::engine::EstimationEngine) with cache misses
//! fanned out at kernel granularity over a shared worker [`Pool`] — a large
//! request saturates every worker instead of pinning one. Inline and file
//! descriptions are compiled through the global
//! [`ArchRegistry`](crate::acadl::text::ArchRegistry), so repeated requests
//! against an unchanged description never recompile it.

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context};

use crate::accel::{GemminiConfig, PlasticineConfig, SystolicConfig, UltraTrailConfig};
use crate::aidg::FixedPointConfig;
use crate::engine::EstimationEngine;
use crate::Result;

use super::job::{resolve_network, Arch, DescribedArch, DescribedNet};
use super::pool::Pool;

/// Parse an architecture spec string.
pub fn parse_arch(spec: &str) -> Result<Arch> {
    let spec = spec.trim();
    if spec.is_empty() {
        bail!("empty architecture spec");
    }
    if let Some(path) = spec.strip_prefix("file:") {
        if path.is_empty() {
            bail!("file: spec needs a path, e.g. file:arch/systolic_16x16.toml");
        }
        return Ok(Arch::Described(DescribedArch::file(path)));
    }
    let parts: Vec<&str> = spec.split(':').collect();
    let head = parts.first().copied().unwrap_or_default();
    match head {
        "systolic" => {
            let dims = parts.get(1).context("systolic needs <rows>x<cols>")?;
            let (r, c) = parse_dims(dims)?;
            let mut cfg = SystolicConfig::new(r, c);
            if let Some(pw) = parts.get(2) {
                let pw = pw
                    .strip_prefix("pw")
                    .context("third field must be pw<N>")?
                    .parse::<u32>()
                    .with_context(|| format!("bad port width in {spec:?}"))?;
                cfg = cfg.with_port_width(pw);
            }
            Ok(Arch::Systolic(cfg))
        }
        "ultratrail" => {
            let mut cfg = UltraTrailConfig::default();
            if let Some(d) = parts.get(1) {
                cfg.array_dim = d
                    .parse()
                    .with_context(|| format!("bad array dimension in {spec:?}"))?;
            }
            Ok(Arch::UltraTrail(cfg))
        }
        "gemmini" => {
            let mut cfg = GemminiConfig::default();
            if let Some(d) = parts.get(1) {
                cfg.dim = d
                    .parse()
                    .with_context(|| format!("bad array dimension in {spec:?}"))?;
            }
            Ok(Arch::Gemmini(cfg))
        }
        "plasticine" => {
            let dims = parts.get(1).context("plasticine needs <rows>x<cols>:<tile>")?;
            let (r, c) = parse_dims(dims)?;
            let tile = parts
                .get(2)
                .context("plasticine needs a tile size (plasticine:<rows>x<cols>:<tile>)")?
                .parse()
                .with_context(|| format!("bad tile size in {spec:?}"))?;
            Ok(Arch::Plasticine(PlasticineConfig::new(r, c, tile)))
        }
        other => bail!(
            "unknown architecture {other:?} (systolic|ultratrail|gemmini|plasticine|file:<path>)"
        ),
    }
}

fn parse_dims(s: &str) -> Result<(u32, u32)> {
    let (r, c) = s.split_once('x').context("expected <rows>x<cols>")?;
    let r = r.parse().with_context(|| format!("bad row count {r:?}"))?;
    let c = c.parse().with_context(|| format!("bad column count {c:?}"))?;
    Ok((r, c))
}

/// Serving knobs (the CLI's `--workers`/`--listen`/`--store` surface).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker threads for kernel-granular fan-out (0 = available
    /// parallelism).
    pub workers: usize,
    /// Concurrent TCP sessions accepted before further connections are
    /// refused with a `busy` line (TCP mode only).
    pub max_clients: usize,
    /// Per-connection read deadline (TCP mode only; `None` waits forever).
    pub read_timeout: Option<Duration>,
    /// Attach the persistent estimate store at this directory.
    pub store: Option<PathBuf>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            workers: 0,
            max_clients: 64,
            read_timeout: Some(Duration::from_secs(60)),
            store: None,
        }
    }
}

/// Serve requests from `input`, writing one result line per request to
/// `output`, with default options. Returns the number of commands served
/// (including failed ones and `describe` registrations).
pub fn serve(input: impl BufRead, output: impl Write) -> Result<usize> {
    serve_with(input, output, &ServeOptions::default())
}

/// [`serve`] with explicit [`ServeOptions`] — the stdio (single-session)
/// entry point. For the concurrent TCP front end see
/// [`super::net::NetServer`].
pub fn serve_with(
    input: impl BufRead,
    mut output: impl Write,
    opts: &ServeOptions,
) -> Result<usize> {
    let pool = Pool::new(opts.workers);
    attach_store_if_configured(opts)?;
    let mut session = Session::new(&pool, None);
    session.run(input, &mut output)?;
    if let Some(store) = EstimationEngine::global().store() {
        store.flush()?;
    }
    Ok(session.served)
}

/// Open `opts.store` (if set) and attach it to the global engine. Shared
/// by the stdio and TCP entry points.
pub(crate) fn attach_store_if_configured(opts: &ServeOptions) -> Result<()> {
    if let Some(dir) = &opts.store {
        let store = crate::engine::EstimateStore::open(dir)
            .with_context(|| format!("opening estimate store {}", dir.display()))?;
        EstimationEngine::global().attach_store(Some(store));
    }
    Ok(())
}

/// How one serve session ended — the TCP front end uses this to decide
/// between closing one connection and draining the whole listener.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SessionEnd {
    /// Input exhausted (client closed the connection / EOF on stdin).
    Eof,
    /// The client sent `quit`.
    Quit,
    /// The client sent `shutdown`, or the server-wide flag was raised.
    Shutdown,
    /// A read hit the per-connection deadline.
    Timeout,
}

/// One protocol session: per-session state (inline descriptions, last
/// sweep, lazily probed roofline backend) over the process-shared engine,
/// cache, store, and worker pool.
pub(crate) struct Session<'p> {
    pool: &'p Pool,
    /// Server-wide shutdown flag (TCP mode); `None` for stdio sessions.
    shutdown: Option<Arc<AtomicBool>>,
    inline_archs: HashMap<String, DescribedArch>,
    inline_nets: HashMap<String, DescribedNet>,
    last_sweep: Option<crate::dse::SweepOutcome>,
    // loaded on the first `sweep` command, then shared by the session —
    // re-probing the XLA artifacts per request would be pure waste
    roofline: Option<crate::dse::RooflineBackend>,
    /// Commands served (including failed ones and `describe` acks).
    pub(crate) served: usize,
}

impl<'p> Session<'p> {
    pub(crate) fn new(pool: &'p Pool, shutdown: Option<Arc<AtomicBool>>) -> Self {
        Self {
            pool,
            shutdown,
            inline_archs: HashMap::new(),
            inline_nets: HashMap::new(),
            last_sweep: None,
            roofline: None,
            served: 0,
        }
    }

    /// Whether the server-wide shutdown flag has been raised.
    fn draining(&self) -> bool {
        self.shutdown.as_ref().is_some_and(|f| f.load(Ordering::Relaxed))
    }

    /// Drive the session until its input ends, the client quits, a read
    /// times out, or shutdown is requested. Every response line is
    /// flushed before the next read — buffered transports (TCP) would
    /// otherwise deadlock a request/response client.
    pub(crate) fn run(
        &mut self,
        input: impl BufRead,
        output: &mut impl Write,
    ) -> Result<SessionEnd> {
        let mut lines = input.lines();
        loop {
            if self.draining() {
                return Ok(SessionEnd::Shutdown);
            }
            let Some(line) = lines.next() else {
                return Ok(SessionEnd::Eof);
            };
            let line = match line {
                Ok(l) => l,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                    ) =>
                {
                    return Ok(SessionEnd::Timeout);
                }
                Err(e) => return Err(e.into()),
            };
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "quit" {
                return Ok(SessionEnd::Quit);
            }
            if line == "shutdown" {
                writeln!(output, "shutting down")?;
                output.flush()?;
                self.served += 1;
                if let Some(flag) = &self.shutdown {
                    flag.store(true, Ordering::Relaxed);
                }
                return Ok(SessionEnd::Shutdown);
            }
            if let Some(name) = line.strip_prefix("network describe ") {
                match read_body("network describe", name.trim(), &mut lines) {
                    Ok((name, body)) => {
                        writeln!(output, "described network @{name}")?;
                        self.inline_nets
                            .insert(name.clone(), DescribedNet::inline(format!("@{name}"), body));
                    }
                    Err(e) => writeln!(output, "error: {e:#}")?,
                }
                output.flush()?;
                self.served += 1;
                continue;
            }
            if let Some(name) = line.strip_prefix("describe ") {
                match read_body("describe", name.trim(), &mut lines) {
                    Ok((name, body)) => {
                        writeln!(output, "described @{name}")?;
                        self.inline_archs
                            .insert(name.clone(), DescribedArch::inline(format!("@{name}"), body));
                    }
                    Err(e) => writeln!(output, "error: {e:#}")?,
                }
                output.flush()?;
                self.served += 1;
                continue;
            }
            let sp = crate::obs::span("serve.request");
            let outcome = self.command(line);
            drop(sp);
            match outcome {
                Ok(msg) => writeln!(output, "{msg}")?,
                Err(e) => writeln!(output, "error: {e:#}")?,
            }
            output.flush()?;
            self.served += 1;
            // periodic persistence: a cheap no-op below the threshold
            if let Some(store) = EstimationEngine::global().store() {
                let _ = store.flush_if_dirty(64);
            }
        }
    }
}

/// Read a `describe`/`network describe` body: raw description lines until
/// `end`. The body is always consumed, even when the name is invalid —
/// otherwise its lines would be executed as server commands.
fn read_body(
    command: &str,
    name: &str,
    lines: &mut impl Iterator<Item = std::io::Result<String>>,
) -> Result<(String, String)> {
    let bad_name = name.is_empty() || name.split_whitespace().count() != 1;
    let mut body = String::new();
    let mut terminated = false;
    for line in lines {
        let line = line?;
        if line.trim() == "end" {
            terminated = true;
            break;
        }
        body.push_str(&line);
        body.push('\n');
    }
    if bad_name {
        bail!("{command} needs a single name ({command} <name>)");
    }
    if !terminated {
        bail!("{command} {name:?} not terminated with `end` before end of input");
    }
    Ok((name.to_string(), body))
}

impl Session<'_> {
    /// Execute one single-line command, returning the (possibly
    /// multi-line) response text.
    fn command(&mut self, line: &str) -> Result<String> {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("estimate") => {
                let spec = it.next().context("estimate <arch> <network>")?;
                let arch = match spec.strip_prefix('@') {
                    Some(name) => Arch::Described(
                        self.inline_archs
                            .get(name)
                            .with_context(|| {
                                format!("no described architecture @{name} (use `describe {name}`)")
                            })?
                            .clone(),
                    ),
                    None => parse_arch(spec)?,
                };
                let netspec = it.next().context("estimate <arch> <network>")?;
                let net = match netspec.strip_prefix('@') {
                    Some(name) => self
                        .inline_nets
                        .get(name)
                        .with_context(|| {
                            format!(
                                "no described network @{name} (use `network describe {name}`)"
                            )
                        })?
                        .network()?,
                    None => resolve_network(netspec)?,
                };
                let e = EstimationEngine::global().estimate_network_pooled(
                    &arch,
                    &net,
                    &FixedPointConfig::default(),
                    self.pool,
                )?;
                let mut line = format!(
                    "{} {} cycles={} evaluated_iters={} total_iters={} kernels={} unique={} \
                     cache_hits={} deduped={} runtime_ms={}",
                    e.arch,
                    e.network,
                    e.total_cycles(),
                    e.evaluated_iters(),
                    e.total_iters(),
                    e.stats.total_kernels,
                    e.stats.unique_kernels,
                    e.stats.cache_hits,
                    e.stats.deduped,
                    e.runtime.as_millis()
                );
                if let Some(cal) = e.calibrated_cycles() {
                    let (lo, hi) = e.ci_bounds().unwrap_or((cal, cal));
                    line.push_str(&format!(" calibrated={cal} ci_lo={lo} ci_hi={hi}"));
                }
                Ok(line)
            }
            Some("calibrate") => match it.next() {
                Some("off") => {
                    EstimationEngine::global().set_calibration(None);
                    Ok("calibration off".to_string())
                }
                Some(path) => {
                    let model = crate::calib::CalibrationModel::load(std::path::Path::new(path))?;
                    let classes = model.class_count();
                    EstimationEngine::global().set_calibration(Some(std::sync::Arc::new(model)));
                    Ok(format!("calibration loaded {path} classes={classes}"))
                }
                None => bail!("calibrate needs an argument (calibrate <file>|off)"),
            },
            Some("sweep") => {
                let spec = it.next().context("sweep <arch> <network> [keep=F] [cap=N]")?;
                let netspec = it.next().context("sweep <arch> <network> [keep=F] [cap=N]")?;
                let mut keep = 1.0f64;
                let mut cap: Option<usize> = None;
                for extra in it {
                    if let Some(v) = extra.strip_prefix("keep=") {
                        keep = v.parse().with_context(|| format!("bad keep= value {v:?}"))?;
                    } else if let Some(v) = extra.strip_prefix("cap=") {
                        cap =
                            Some(v.parse().with_context(|| format!("bad cap= value {v:?}"))?);
                    } else {
                        bail!("unknown sweep option {extra:?} (keep=F | cap=N)");
                    }
                }
                let (src, origin) = match spec.strip_prefix('@') {
                    Some(name) => {
                        let d = self.inline_archs.get(name).with_context(|| {
                            format!("no described architecture @{name} (use `describe {name}`)")
                        })?;
                        match &d.source {
                            super::job::ArchSource::Inline { text, .. } => {
                                (text.to_string(), format!("@{name}"))
                            }
                            super::job::ArchSource::File(p) => (
                                std::fs::read_to_string(p).with_context(|| {
                                    format!("reading architecture description {}", p.display())
                                })?,
                                p.display().to_string(),
                            ),
                        }
                    }
                    None => match spec.strip_prefix("file:") {
                        Some(path) if !path.is_empty() => (
                            std::fs::read_to_string(path).with_context(|| {
                                format!("reading architecture description {path}")
                            })?,
                            path.to_string(),
                        ),
                        _ => bail!(
                            "sweep needs a described architecture (file:<path> or @name) — \
                             builder specs have no [sweep] section"
                        ),
                    },
                };
                let space = crate::dse::SweepSpace::from_source(&src, &origin, cap)?;
                let net = match netspec.strip_prefix('@') {
                    Some(name) => self
                        .inline_nets
                        .get(name)
                        .with_context(|| {
                            format!("no described network @{name} (use `network describe {name}`)")
                        })?
                        .network()?,
                    None => resolve_network(netspec)?,
                };
                let opts = crate::dse::SweepOptions { keep_frac: keep, ..Default::default() };
                let backend = self.roofline.get_or_insert_with(crate::dse::RooflineBackend::auto);
                let mut outcome = crate::dse::explore_space(
                    &space,
                    &net,
                    &opts,
                    self.pool,
                    backend,
                    EstimationEngine::global(),
                )?;
                // frontier persistence: with a store attached, fold the prior
                // frontier for this (sweep space × network) into the fresh
                // outcome, then persist the merged frontier back
                let mut resumed_note = String::new();
                if let Some(store) = EstimationEngine::global().store() {
                    let sd = crate::engine::store::fnv64(src.as_bytes());
                    let nd = crate::engine::store::net_digest(&net);
                    let prior = store.frontier_get(sd, nd);
                    let resumed = prior.as_ref().map_or(0, Vec::len);
                    if let Some(prior) = prior {
                        crate::dse::merge_frontier(prior, &mut outcome);
                    }
                    store.frontier_put(
                        sd,
                        nd,
                        outcome.frontier().into_iter().cloned().collect(),
                    );
                    resumed_note = format!(" resumed={resumed}");
                }
                let best = outcome.points.first();
                let line = format!(
                    "sweep {origin} {} enumerated={} skipped={} estimated={} frontier={} \
                     best={} best_cycles={} hit_rate={:.4} wall_ms={}{resumed_note}",
                    net.name,
                    outcome.enumerated,
                    outcome.skipped,
                    outcome.estimated,
                    outcome.frontier().len(),
                    best.map(|p| p.label.clone()).unwrap_or_else(|| "-".into()),
                    best.and_then(|p| p.aidg_cycles).unwrap_or(0),
                    outcome.warm_hit_rate(),
                    outcome.wall.as_millis(),
                );
                self.last_sweep = Some(outcome);
                Ok(line)
            }
            Some("frontier") => {
                let outcome = self
                    .last_sweep
                    .as_ref()
                    .context("no sweep has run yet (run `sweep <arch> <network>` first)")?;
                let frontier = outcome.frontier();
                let mut out = format!("frontier points={}", frontier.len());
                for p in frontier {
                    out.push_str(&format!(
                        "\npoint {} arch={} cycles={} pe={} mem_words={}",
                        p.label,
                        p.arch_name,
                        p.aidg_cycles.unwrap_or(0),
                        p.pe_count,
                        p.mem_words
                    ));
                }
                Ok(out)
            }
            Some("store") => {
                let sub = it.next().context("store needs an argument (store stats|flush|gc)")?;
                let store = EstimationEngine::global()
                    .store()
                    .context("no store attached (start serve with --store <dir>)")?;
                match sub {
                    "stats" => {
                        let s = store.stats();
                        Ok(format!(
                            "store dir={} entries={} frontiers={} dirty={} segments={} gen={}",
                            store.dir().display(),
                            s.entries,
                            s.frontiers,
                            s.dirty,
                            s.segments,
                            s.open_gen,
                        ))
                    }
                    "flush" => {
                        let n = store.flush()?;
                        Ok(format!("store flushed records={n}"))
                    }
                    "gc" => {
                        let o = store.gc()?;
                        Ok(format!("store gc kept={} dropped={}", o.kept, o.dropped))
                    }
                    other => bail!("unknown store subcommand {other:?} (store stats|flush|gc)"),
                }
            }
            Some("stats") => {
                let s = EstimationEngine::global().stats();
                let mut line = format!(
                    "stats workers={} requests={} kernels={} evaluated={} deduped={} \
                     cache_entries={} cache_cap={} cache_hits={} cache_misses={} evictions={} \
                     arch_compiles={} net_compiles={}",
                    self.pool.workers(),
                    s.requests,
                    s.kernels_total,
                    s.kernels_evaluated,
                    s.kernels_deduped,
                    s.cache.entries,
                    s.cache.capacity,
                    s.cache.hits,
                    s.cache.misses,
                    s.cache.evictions,
                    crate::acadl::text::ArchRegistry::global().compile_count(),
                    crate::dnn::text::NetRegistry::global().compile_count(),
                );
                line.push_str(&format!(
                    " calib_classes={}",
                    EstimationEngine::global().calibration().map(|m| m.class_count()).unwrap_or(0)
                ));
                // process-wide counters cover every engine in the process (the
                // global one above plus any locally constructed ones)
                for (name, value) in crate::metrics::counters::snapshot() {
                    line.push_str(&format!(" {name}={value}"));
                }
                Ok(line)
            }
            Some("metrics") => {
                // one stable machine-readable line: flag + ring accounting,
                // then counters, gauges, and per-span latency summaries (spans
                // name-sorted by the snapshot)
                let snap = crate::obs::snapshot();
                let mut line = format!(
                    "metrics enabled={} events={} dropped={}",
                    u8::from(snap.enabled),
                    snap.events_recorded,
                    snap.events_dropped
                );
                for (name, value) in &snap.counters {
                    line.push_str(&format!(" {name}={value}"));
                }
                for (name, value) in &snap.gauges {
                    line.push_str(&format!(" {name}={value}"));
                }
                for s in &snap.spans {
                    let h = s.summary;
                    line.push_str(&format!(
                        " span.{0}.count={1} span.{0}.total_ns={2} span.{0}.self_ns={3} \
                         span.{0}.p50_ns={4} span.{0}.p95_ns={5} span.{0}.max_ns={6}",
                        s.name, h.count, h.total_ns, h.self_ns, h.p50_ns, h.p95_ns, h.max_ns
                    ));
                }
                Ok(line)
            }
            Some("trace") => match it.next() {
                Some("on") => {
                    crate::obs::set_enabled(true);
                    Ok("trace on".to_string())
                }
                Some("off") => {
                    crate::obs::set_enabled(false);
                    Ok("trace off".to_string())
                }
                _ => bail!("trace needs an argument (trace on|off)"),
            },
            Some(cmd) => {
                bail!(
                    "unknown command {cmd:?} (estimate|describe|network describe|sweep|frontier|\
                     calibrate|store|stats|metrics|trace|shutdown|quit)"
                )
            }
            None => bail!("empty command"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_arch_specs() {
        assert!(matches!(parse_arch("systolic:4x4").unwrap(), Arch::Systolic(c) if c.rows == 4));
        let pw = parse_arch("systolic:12x12:pw7").unwrap();
        assert!(matches!(pw, Arch::Systolic(c) if c.port_width == 7));
        assert!(matches!(parse_arch("ultratrail").unwrap(), Arch::UltraTrail(c) if c.array_dim == 8));
        assert!(matches!(parse_arch("gemmini:32").unwrap(), Arch::Gemmini(c) if c.dim == 32));
        assert!(
            matches!(parse_arch("plasticine:3x6:16").unwrap(), Arch::Plasticine(c) if c.tile == 16)
        );
        assert!(parse_arch("tpu").is_err());
        assert!(parse_arch("systolic").is_err());
        assert!(parse_arch("plasticine:3x6").is_err());
    }

    #[test]
    fn malformed_specs_are_errors_not_panics() {
        for bad in [
            "",
            " ",
            ":",
            "::",
            "systolic:",
            "systolic:x",
            "systolic:4x",
            "systolic:x4",
            "systolic:4x4:7",
            "systolic:4x4:pwx",
            "ultratrail:big",
            "gemmini:-1",
            "plasticine:",
            "plasticine:4x4",
            "plasticine:4x4:t",
            "file:",
        ] {
            assert!(parse_arch(bad).is_err(), "spec {bad:?} should fail to parse");
        }
    }

    #[test]
    fn file_spec_parses_to_described_arch() {
        match parse_arch("file:arch/systolic_16x16.toml").unwrap() {
            Arch::Described(d) => assert_eq!(d.label(), "arch/systolic_16x16.toml"),
            other => panic!("expected described arch, got {other:?}"),
        }
    }

    #[test]
    fn serve_estimates_and_reports_errors() {
        let input = "# comment\nestimate ultratrail tc_resnet8\nestimate ultratrail alexnet\nbogus\nquit\n";
        let mut out = Vec::new();
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        assert_eq!(served, 3);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].contains("cycles="), "{}", lines[0]);
        assert!(lines[1].starts_with("error:"));
        assert!(lines[2].starts_with("error:"));
    }

    #[test]
    fn serve_reports_engine_stats_and_cache_reuse() {
        // the same request twice: the second line must show cache reuse
        // (no kernel evaluated twice process-wide); `stats` reports counters
        let input = "estimate systolic:2x2 tc_resnet8\n\
                     estimate systolic:2x2 tc_resnet8\nstats\nquit\n";
        let mut out = Vec::new();
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        assert_eq!(served, 3);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].contains("unique="), "{}", lines[0]);
        let kernels_of = |line: &str, field: &str| -> u64 {
            line.split_whitespace()
                .find_map(|t| t.strip_prefix(field))
                .unwrap_or_else(|| panic!("no {field} in {line}"))
                .parse()
                .unwrap()
        };
        // warm request: every kernel served from cache or intra-request dedup
        let total = kernels_of(lines[1], "kernels=");
        let hits = kernels_of(lines[1], "cache_hits=");
        let dedup = kernels_of(lines[1], "deduped=");
        assert_eq!(hits + dedup, total, "{}", lines[1]);
        // cycle-identical across cold and warm
        assert_eq!(
            lines[0].split_whitespace().find(|t| t.starts_with("cycles=")),
            lines[1].split_whitespace().find(|t| t.starts_with("cycles="))
        );
        assert!(lines[2].starts_with("stats "), "{}", lines[2]);
        assert!(lines[2].contains("cache_entries="), "{}", lines[2]);
    }

    #[test]
    fn serve_sweep_and_frontier_commands() {
        let input = "frontier\n\
                     sweep ultratrail tc_resnet8\n\
                     sweep file:arch/ultratrail_8x8.toml tc_resnet8 keep=1.0\n\
                     frontier\n\
                     sweep file:arch/ultratrail_8x8.toml tc_resnet8 keep=bogus\n\
                     stats\nquit\n";
        let mut out = Vec::new();
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        assert_eq!(served, 6);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // frontier before any sweep, and builder specs, are clean errors
        assert!(lines[0].contains("no sweep has run yet"), "{}", lines[0]);
        assert!(lines[1].contains("builder specs have no [sweep]"), "{}", lines[1]);
        assert!(lines[2].starts_with("sweep arch/ultratrail_8x8.toml tc_resnet8"), "{}", lines[2]);
        assert!(lines[2].contains("estimated="), "{}", lines[2]);
        assert!(lines[2].contains("best=array_dim="), "{}", lines[2]);
        // frontier: header + one line per point
        assert!(lines[3].starts_with("frontier points="), "{}", lines[3]);
        let n: usize = lines[3].split('=').next_back().unwrap().parse().unwrap();
        assert!(n >= 1);
        for p in &lines[4..4 + n] {
            assert!(p.starts_with("point array_dim="), "{p}");
            assert!(p.contains("cycles="), "{p}");
        }
        assert!(lines[4 + n].contains("bad keep= value"), "{}", lines[4 + n]);
        // stats surfaces the dse counters (dotted naming convention)
        let stats = lines[5 + n];
        assert!(stats.contains("dse.points.enumerated="), "{stats}");
        assert!(stats.contains("dse.points.estimated="), "{stats}");
    }

    #[test]
    fn serve_metrics_and_trace_commands() {
        // serialize against other tests that toggle the tracing flag
        let _lock = crate::obs::test_lock();
        let input = "metrics\ntrace on\ntrace off\ntrace sideways\nquit\n";
        let mut out = Vec::new();
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        assert_eq!(served, 4);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // one machine-readable line: flag/ring accounting, counters, gauges
        assert!(lines[0].starts_with("metrics enabled="), "{}", lines[0]);
        assert!(lines[0].contains(" events="), "{}", lines[0]);
        assert!(lines[0].contains(" dropped="), "{}", lines[0]);
        assert!(lines[0].contains(" engine.requests="), "{}", lines[0]);
        assert!(lines[0].contains(" pool.queue_depth="), "{}", lines[0]);
        assert!(lines[0].contains(" pool.inflight="), "{}", lines[0]);
        assert!(lines[0].contains(" cache.entries="), "{}", lines[0]);
        // every k=v token is machine-parsable
        for tok in lines[0].split_whitespace().skip(1) {
            let (k, v) = tok.split_once('=').unwrap_or_else(|| panic!("bad token {tok}"));
            assert!(!k.is_empty(), "{tok}");
            assert!(v.parse::<i64>().is_ok(), "non-numeric value in {tok}");
        }
        assert_eq!(lines[1], "trace on");
        assert_eq!(lines[2], "trace off");
        assert!(lines[3].contains("trace needs an argument"), "{}", lines[3]);
        // the toggles actually moved the flag: off after `trace off`
        assert!(!crate::obs::enabled());
    }

    #[test]
    fn serve_calibrate_command() {
        let input = "calibrate off\ncalibrate\ncalibrate /no/such/model.txt\nstats\nquit\n";
        let mut out = Vec::new();
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        assert_eq!(served, 4);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "calibration off");
        assert!(lines[1].contains("calibrate needs an argument"), "{}", lines[1]);
        assert!(lines[2].starts_with("error:"), "{}", lines[2]);
        assert!(lines[3].contains("calib_classes="), "{}", lines[3]);
    }

    #[test]
    fn serve_unknown_inline_arch_is_an_error() {
        let input = "estimate @nope tc_resnet8\nquit\n";
        let mut out = Vec::new();
        serve(std::io::Cursor::new(input), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("no described architecture @nope"), "{text}");
    }

    #[test]
    fn serve_network_describe_registers_inline_nets() {
        let input = format!(
            "network describe tiny\n{}end\n\
             estimate ultratrail @tiny\n\
             estimate ultratrail net:net/tc_resnet8.toml\n\
             estimate ultratrail @nope\nquit\n",
            crate::dnn::text::compile::tests::TINY_NET
        );
        let mut out = Vec::new();
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        assert_eq!(served, 4);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "described network @tiny");
        assert!(lines[1].starts_with("ultratrail8x8 tiny8 cycles="), "{}", lines[1]);
        assert!(
            lines[2].starts_with("ultratrail8x8 tc_resnet8 cycles="),
            "{}",
            lines[2]
        );
        assert!(lines[3].contains("no described network @nope"), "{}", lines[3]);
        // unterminated network describe is an error
        let mut out = Vec::new();
        serve(std::io::Cursor::new("network describe x\n[net]\n"), &mut out).unwrap();
        assert!(String::from_utf8(out).unwrap().contains("not terminated"));
    }

    /// A writer that counts flushes — pins the invariant that every
    /// response line reaches the transport before the next read.
    struct FlushCounter {
        buf: Vec<u8>,
        flushes: usize,
    }

    impl Write for FlushCounter {
        fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
            self.buf.write(data)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            self.flushes += 1;
            Ok(())
        }
    }

    #[test]
    fn serve_flushes_after_every_response() {
        // three responses (estimate error, describe ack, stats) — a
        // buffered transport must see each before the client's next write
        let input = "estimate bogus tc_resnet8\ndescribe d\n[arch]\nend\nstats\nquit\n";
        let mut out = FlushCounter { buf: Vec::new(), flushes: 0 };
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        assert_eq!(served, 3);
        assert!(
            out.flushes >= served,
            "{} responses but only {} flushes",
            served,
            out.flushes
        );
    }

    #[test]
    fn serve_shutdown_acks_and_ends_the_session() {
        let input = "shutdown\nestimate ultratrail tc_resnet8\n";
        let mut out = Vec::new();
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        // the command after shutdown is never served
        assert_eq!(served, 1);
        assert_eq!(String::from_utf8(out).unwrap(), "shutting down\n");
    }

    #[test]
    fn serve_store_commands_without_a_store_are_clean_errors() {
        let input = "store stats\nstore\nstore polish\nquit\n";
        let mut out = Vec::new();
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        assert_eq!(served, 3);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].contains("no store attached"), "{}", lines[0]);
        assert!(lines[1].contains("store needs an argument"), "{}", lines[1]);
        // subcommand validation happens after the attachment check, so an
        // unattached store reports the missing store first
        assert!(lines[2].contains("no store attached"), "{}", lines[2]);
    }

    #[test]
    fn serve_describe_registers_inline_archs() {
        // a body that parses but fails validation exercises the protocol
        // without needing a full architecture in the test
        let input = "describe broken\n[arch]\nname = \"x\"\nend\nestimate @broken tc_resnet8\nquit\n";
        let mut out = Vec::new();
        let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
        assert_eq!(served, 2);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("described @broken"), "{text}");
        // the estimate against the incomplete description must fail cleanly
        assert!(text.contains("error:"), "{text}");
        // unterminated describe is an error
        let mut out = Vec::new();
        serve(std::io::Cursor::new("describe x\n[arch]\n"), &mut out).unwrap();
        assert!(String::from_utf8(out).unwrap().contains("not terminated"));
    }
}
