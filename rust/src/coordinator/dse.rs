//! Legacy Plasticine design-space exploration API — now a compatibility
//! shim over the architecture-generic [`crate::dse`] subsystem.
//!
//! The original driver hardcoded a Plasticine rows × cols × tile grid.
//! [`DseSpec`]/[`DsePoint`]/[`explore`] keep that exact surface (and
//! cycle-identical results: candidates still instantiate the hand-built
//! [`crate::accel::Plasticine`] model), but the two-phase flow — roofline
//! pre-filter, locality-scheduled accurate pass — runs through
//! [`crate::dse::explore_candidates`] like any described sweep.
//! [`DseSpec::to_sweep_description`] renders the equivalent `[sweep]`
//! space over `arch/plasticine_3x6.toml`; `rust/tests/dse_generic.rs` pins
//! the two grids cycle-for-cycle.

use crate::aidg::FixedPointConfig;
use crate::dse::{explore_candidates, CandidateArch, Schedule, SweepOptions};
use crate::engine::EstimationEngine;

use crate::accel::PlasticineConfig;
use crate::Result;

use super::job::Arch;
use super::pool::Pool;

pub use crate::dse::RooflineBackend;

/// The swept Plasticine parameter grid (legacy spelling of a `[sweep]`
/// space over `arch/plasticine_3x6.toml`).
#[derive(Debug, Clone)]
pub struct DseSpec {
    /// Row counts to sweep.
    pub rows: Vec<u32>,
    /// Column counts to sweep.
    pub cols: Vec<u32>,
    /// PCU GEMM tile sizes to sweep.
    pub tiles: Vec<u32>,
    /// Network spec ([`super::job::resolve_network`]).
    pub network: String,
    /// Fraction of designs surviving the roofline pre-filter into the
    /// accurate pass (1.0 = estimate everything, as Fig. 15 plots).
    pub keep_frac: f64,
    /// Fixed-point estimator configuration.
    pub fp: FixedPointConfig,
}

impl DseSpec {
    /// The grid as explorer candidates (hand-built Plasticine models, so
    /// the shim is cycle-identical to the pre-refactor driver).
    fn candidates(&self) -> Vec<CandidateArch> {
        let mut cands = Vec::new();
        for &r in &self.rows {
            for &c in &self.cols {
                for &t in &self.tiles {
                    cands.push(CandidateArch {
                        label: format!("rows={r},cols={c},tile={t}"),
                        arch: Arch::Plasticine(PlasticineConfig::new(r, c, t)),
                        assignment: vec![
                            ("rows".into(), r as i64),
                            ("cols".into(), c as i64),
                            ("tile".into(), t as i64),
                        ],
                    });
                }
            }
        }
        cands
    }

    /// Compile this grid to the equivalent described `[sweep]` space: the
    /// shipped `arch/plasticine_3x6.toml` with its `[sweep]` replaced by
    /// the spec's rows/cols/tiles lists.
    pub fn to_sweep_description(&self) -> Result<crate::acadl::text::Description> {
        use crate::acadl::text::ast::{Span, Spanned, Sweep, SweepDim, SweepItem};
        use crate::acadl::text::PExpr;
        let src = include_str!("../../../arch/plasticine_3x6.toml");
        let mut desc = crate::acadl::text::parse(src)
            .map_err(|d| anyhow::anyhow!("{}", d.render("arch/plasticine_3x6.toml")))?;
        let dim = |name: &str, values: &[u32]| SweepDim {
            name: Spanned::bare(name.to_string()),
            items: values
                .iter()
                .map(|&v| SweepItem::Scalar(PExpr::Const(v as i64)))
                .collect(),
            span: Span::default(),
        };
        desc.sweep = Some(Sweep {
            dims: vec![
                dim("rows", &self.rows),
                dim("cols", &self.cols),
                dim("tile", &self.tiles),
            ],
            when: None,
            cap: None,
            span: Span::default(),
        });
        Ok(desc)
    }
}

/// One explored design point (legacy projection of
/// [`crate::dse::SweepPoint`]).
#[derive(Debug, Clone)]
pub struct DsePoint {
    /// Array rows.
    pub rows: u32,
    /// Array columns.
    pub cols: u32,
    /// PCU GEMM tile size.
    pub tile: u32,
    /// Whole-network refined-roofline cycles (phase 1).
    pub roofline_cycles: f64,
    /// Whole-network AIDG cycles (phase 2; None if filtered out).
    pub aidg_cycles: Option<u64>,
}

/// Run the exploration. Returns every grid point with its roofline estimate
/// and (for survivors) its AIDG estimate, sorted best-AIDG-first where
/// available — the exact pre-refactor contract, served by the generic
/// explorer (global engine, locality-scheduled accurate pass).
pub fn explore(spec: &DseSpec, pool: &Pool, backend: &RooflineBackend) -> Result<Vec<DsePoint>> {
    let net = super::job::resolve_network(&spec.network)?;
    let opts = SweepOptions {
        keep_frac: spec.keep_frac,
        fp: spec.fp,
        schedule: Schedule::Locality,
        batch: true,
    };
    let outcome = explore_candidates(
        spec.candidates(),
        &net,
        &opts,
        pool,
        backend,
        EstimationEngine::global(),
    )?;
    Ok(outcome
        .points
        .into_iter()
        .map(|p| {
            let field = |name: &str| {
                p.assignment
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, v)| *v as u32)
                    .unwrap_or_default()
            };
            DsePoint {
                rows: field("rows"),
                cols: field("cols"),
                tile: field("tile"),
                roofline_cycles: p.roofline_cycles,
                aidg_cycles: p.aidg_cycles,
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_dse_runs_and_ranks() {
        let spec = DseSpec {
            rows: vec![2, 3],
            cols: vec![2, 4],
            tiles: vec![8, 16],
            network: "tc_resnet8".into(),
            keep_frac: 0.5,
            fp: FixedPointConfig::default(),
        };
        let pool = Pool::new(4);
        let backend = RooflineBackend::Native;
        let points = explore(&spec, &pool, &backend).unwrap();
        assert_eq!(points.len(), 8);
        let with_aidg = points.iter().filter(|p| p.aidg_cycles.is_some()).count();
        assert_eq!(with_aidg, 4); // keep_frac 0.5
        // results sorted: survivors first, by AIDG cycles ascending
        let cycles: Vec<u64> = points.iter().filter_map(|p| p.aidg_cycles).collect();
        assert!(cycles.windows(2).all(|w| w[0] <= w[1]));
        assert!(points.iter().all(|p| p.roofline_cycles > 0.0));
    }

    #[test]
    fn keep_all_estimates_everything() {
        let spec = DseSpec {
            rows: vec![2],
            cols: vec![2, 3],
            tiles: vec![8],
            network: "tc_resnet8".into(),
            keep_frac: 1.0,
            fp: FixedPointConfig::default(),
        };
        let pool = Pool::new(2);
        let points = explore(&spec, &pool, &RooflineBackend::Native).unwrap();
        assert!(points.iter().all(|p| p.aidg_cycles.is_some()));
    }

    #[test]
    fn spec_renders_an_equivalent_sweep_description() {
        let spec = DseSpec {
            rows: vec![2, 3],
            cols: vec![4],
            tiles: vec![8, 16],
            network: "tc_resnet8".into(),
            keep_frac: 1.0,
            fp: FixedPointConfig::default(),
        };
        let desc = spec.to_sweep_description().unwrap();
        let space =
            crate::dse::SweepSpace::from_description(desc, "plasticine-shim", None).unwrap();
        assert_eq!(space.len_bound(), 4);
        let labels: Vec<String> =
            space.candidates().map(|c| c.unwrap().label()).collect();
        assert_eq!(labels[0], "rows=2,cols=4,tile=8");
        assert_eq!(labels.len(), 4);
    }
}
