//! Design-space exploration driver (paper §7.4, Fig. 15).
//!
//! Sweeps Plasticine-derived architecture parameters (rows × cols × PCU
//! GEMM tile size) against a set of networks in two phases:
//!
//! 1. **Roofline pre-filter** — every design point's per-layer refined
//!    roofline estimate, batched through the AOT-compiled XLA estimator
//!    ([`crate::runtime::RooflineExec`]) when available (native mirror
//!    otherwise). Milliseconds for thousands of points.
//! 2. **Accurate pass** — the surviving fraction gets full AIDG fixed-point
//!    estimates on the worker pool.
//!
//! This is the loop the paper motivates: exclude designs that cannot win
//! *before* paying for accurate estimation, and never write RTL for any of
//! them.

use crate::accel::PlasticineConfig;
use crate::aidg::FixedPointConfig;
use crate::baselines::roofline::{roofline_cycles, LayerFeatures};

use crate::Result;

use super::job::{Arch, EstimateRequest};
use super::pool::Pool;

/// The swept parameter grid.
#[derive(Debug, Clone)]
pub struct DseSpec {
    /// Row counts to sweep.
    pub rows: Vec<u32>,
    /// Column counts to sweep.
    pub cols: Vec<u32>,
    /// PCU GEMM tile sizes to sweep.
    pub tiles: Vec<u32>,
    /// Network spec ([`super::job::resolve_network`]).
    pub network: String,
    /// Fraction of designs surviving the roofline pre-filter into the
    /// accurate pass (1.0 = estimate everything, as Fig. 15 plots).
    pub keep_frac: f64,
    /// Fixed-point estimator configuration.
    pub fp: FixedPointConfig,
}

/// One explored design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    /// Array rows.
    pub rows: u32,
    /// Array columns.
    pub cols: u32,
    /// PCU GEMM tile size.
    pub tile: u32,
    /// Whole-network refined-roofline cycles (phase 1).
    pub roofline_cycles: f64,
    /// Whole-network AIDG cycles (phase 2; None if filtered out).
    pub aidg_cycles: Option<u64>,
}

/// Roofline batch source: XLA executable or the native mirror.
pub enum RooflineBackend {
    /// Batched through the AOT XLA executable.
    Xla(crate::runtime::RooflineExec),
    /// The native Rust mirror.
    Native,
}

impl RooflineBackend {
    /// Load the XLA backend, falling back to the native mirror when the
    /// artifacts are not built.
    pub fn auto() -> Self {
        match crate::runtime::RooflineExec::load() {
            Ok(x) => RooflineBackend::Xla(x),
            Err(_) => RooflineBackend::Native,
        }
    }

    fn estimate(
        &self,
        layers: &[LayerFeatures],
        hw: &crate::baselines::roofline::HwFeatures,
    ) -> Result<Vec<f64>> {
        match self {
            RooflineBackend::Xla(x) => x.estimate(layers, hw),
            RooflineBackend::Native => {
                Ok(layers.iter().map(|l| roofline_cycles(l, hw)).collect())
            }
        }
    }
}

/// Run the exploration. Returns every grid point with its roofline estimate
/// and (for survivors) its AIDG estimate, sorted best-AIDG-first where
/// available. The accurate pass runs through the worker pool and the global
/// estimation engine, so repeated kernel shapes within each design point's
/// network are priced once per point.
pub fn explore(spec: &DseSpec, pool: &Pool, backend: &RooflineBackend) -> Result<Vec<DsePoint>> {
    let net = super::job::resolve_network(&spec.network)?;

    // ---- phase 1: roofline everything --------------------------------------
    let mut points: Vec<DsePoint> = Vec::new();
    let mut configs: Vec<PlasticineConfig> = Vec::new();
    for &r in &spec.rows {
        for &c in &spec.cols {
            for &t in &spec.tiles {
                let cfg = PlasticineConfig::new(r, c, t);
                let arch = Arch::Plasticine(cfg);
                let mapper = match arch.mapper() {
                    Ok(m) => m,
                    Err(_) => continue, // degenerate grid (e.g. 1×1)
                };
                let mapped = mapper.map_network(&net)?;
                let feats: Vec<LayerFeatures> = net
                    .layers
                    .iter()
                    .zip(&mapped)
                    .filter(|(_, m)| !m.fused)
                    .map(|(l, m)| LayerFeatures::from_mapping(l, m))
                    .collect();
                let hw = mapper.hw_features();
                let cycles = backend.estimate(&feats, &hw)?;
                points.push(DsePoint {
                    rows: r,
                    cols: c,
                    tile: t,
                    roofline_cycles: cycles.iter().sum(),
                    aidg_cycles: None,
                });
                configs.push(cfg);
            }
        }
    }

    // ---- phase 2: accurate AIDG on the survivors ----------------------------
    let keep = ((points.len() as f64 * spec.keep_frac).ceil() as usize).clamp(1, points.len());
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| points[a].roofline_cycles.total_cmp(&points[b].roofline_cycles));
    let survivors: Vec<usize> = order.into_iter().take(keep).collect();

    let reqs: Vec<EstimateRequest> = survivors
        .iter()
        .map(|&i| EstimateRequest {
            arch: Arch::Plasticine(configs[i]),
            network: spec.network.clone(),
            fp: spec.fp,
        })
        .collect();
    let results = pool.run_all(reqs);
    for (&i, r) in survivors.iter().zip(results) {
        points[i].aidg_cycles = Some(r?.total_cycles());
    }

    points.sort_by(|a, b| match (a.aidg_cycles, b.aidg_cycles) {
        (Some(x), Some(y)) => x.cmp(&y),
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => a.roofline_cycles.total_cmp(&b.roofline_cycles),
    });
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_dse_runs_and_ranks() {
        let spec = DseSpec {
            rows: vec![2, 3],
            cols: vec![2, 4],
            tiles: vec![8, 16],
            network: "tc_resnet8".into(),
            keep_frac: 0.5,
            fp: FixedPointConfig::default(),
        };
        let pool = Pool::new(4);
        let backend = RooflineBackend::Native;
        let points = explore(&spec, &pool, &backend).unwrap();
        assert_eq!(points.len(), 8);
        let with_aidg = points.iter().filter(|p| p.aidg_cycles.is_some()).count();
        assert_eq!(with_aidg, 4); // keep_frac 0.5
        // results sorted: survivors first, by AIDG cycles ascending
        let cycles: Vec<u64> = points.iter().filter_map(|p| p.aidg_cycles).collect();
        assert!(cycles.windows(2).all(|w| w[0] <= w[1]));
        assert!(points.iter().all(|p| p.roofline_cycles > 0.0));
    }

    #[test]
    fn keep_all_estimates_everything() {
        let spec = DseSpec {
            rows: vec![2],
            cols: vec![2, 3],
            tiles: vec![8],
            network: "tc_resnet8".into(),
            keep_frac: 1.0,
            fp: FixedPointConfig::default(),
        };
        let pool = Pool::new(2);
        let points = explore(&spec, &pool, &RooflineBackend::Native).unwrap();
        assert!(points.iter().all(|p| p.aidg_cycles.is_some()));
    }
}
