//! Concurrent TCP front end for the serve protocol.
//!
//! [`NetServer`] wraps a [`std::net::TcpListener`] accept loop around the
//! same line protocol the stdio loop speaks (see [`super::server`] and
//! `docs/serve-protocol.md`): one thread per connection up to
//! [`ServeOptions::max_clients`], each running its own [`Session`] —
//! per-connection inline descriptions and last sweep, over the
//! process-shared [`EstimationEngine`](crate::engine::EstimationEngine),
//! estimate cache, persistent store, and worker [`Pool`]. Kernel
//! evaluations from every connection fan out over the one pool, and
//! identical in-flight kernels collapse to a single evaluation through
//! the engine's single-flight map.
//!
//! Overload and lifecycle semantics:
//!
//! - past the client cap a connection is refused with a single `busy`
//!   line and closed (counted by `serve.busy_rejects`);
//! - a read idle past [`ServeOptions::read_timeout`] ends the session
//!   with a `timeout` line;
//! - `shutdown` from any client (or [`ShutdownHandle::shutdown`]) raises
//!   the server-wide flag: the accept loop stops, live sessions finish
//!   their current request and drain, and the store is flushed before
//!   [`NetServer::run`] returns.

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Context;

use crate::engine::EstimationEngine;
use crate::metrics::counters::{SERVE_BUSY_REJECTS, SERVE_SESSIONS};
use crate::Result;

use super::pool::Pool;
use super::server::{attach_store_if_configured, ServeOptions, Session, SessionEnd};

/// A bound-but-not-yet-serving TCP server. [`NetServer::run`] consumes it
/// and blocks until shutdown.
pub struct NetServer {
    listener: TcpListener,
    local: SocketAddr,
    opts: ServeOptions,
    shutdown: Arc<AtomicBool>,
}

/// Raises the server-wide shutdown flag from another thread and wakes the
/// accept loop with a throwaway connection.
#[derive(Clone)]
pub struct ShutdownHandle {
    flag: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ShutdownHandle {
    /// Request a graceful drain: stop accepting, let live sessions finish.
    pub fn shutdown(&self) {
        self.flag.store(true, Ordering::Relaxed);
        // the accept loop only re-checks the flag when `accept` returns —
        // poke it with a connection it will immediately discard
        let _ = TcpStream::connect(self.addr);
    }
}

/// What one server run handled, returned by [`NetServer::run`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetServeOutcome {
    /// Connections accepted into a session (refused ones excluded).
    pub sessions: usize,
    /// Protocol commands served across all sessions.
    pub requests: usize,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:7474`; port 0 picks a free port) and
    /// attach the persistent store if `opts.store` is set. The listener
    /// is live after this returns — clients can connect before
    /// [`run`](Self::run) starts accepting, they just queue in the OS
    /// backlog.
    pub fn bind(addr: &str, opts: ServeOptions) -> Result<Self> {
        attach_store_if_configured(&opts)?;
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding listener on {addr}"))?;
        let local = listener.local_addr()?;
        Ok(Self { listener, local, opts, shutdown: Arc::new(AtomicBool::new(false)) })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// A handle that can stop this server from another thread.
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { flag: Arc::clone(&self.shutdown), addr: self.local }
    }

    /// Accept and serve connections until shutdown, then drain: join every
    /// session thread and flush the store. Returns run-level accounting.
    pub fn run(self) -> Result<NetServeOutcome> {
        let pool = Arc::new(Pool::new(self.opts.workers));
        let requests = Arc::new(AtomicUsize::new(0));
        let mut handles: Vec<JoinHandle<()>> = Vec::new();
        let mut sessions = 0usize;
        for conn in self.listener.incoming() {
            if self.shutdown.load(Ordering::Relaxed) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                // transient accept failures (e.g. a client that reset
                // between accept and handshake) don't stop the server
                Err(_) => continue,
            };
            handles.retain(|h| !h.is_finished());
            if handles.len() >= self.opts.max_clients {
                SERVE_BUSY_REJECTS.add(1);
                let mut stream = stream;
                let _ = stream.write_all(b"busy\n");
                continue;
            }
            sessions += 1;
            SERVE_SESSIONS.add(1);
            let pool = Arc::clone(&pool);
            let flag = Arc::clone(&self.shutdown);
            let requests = Arc::clone(&requests);
            let opts = self.opts.clone();
            let local = self.local;
            handles.push(std::thread::spawn(move || {
                let _g = crate::obs::gauge::SERVE_ACTIVE_SESSIONS.raii();
                let served = handle_connection(stream, &pool, &flag, &opts);
                requests.fetch_add(served, Ordering::Relaxed);
                // a session-initiated `shutdown` must wake the accept loop
                if flag.load(Ordering::Relaxed) {
                    let _ = TcpStream::connect(local);
                }
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        if let Some(store) = EstimationEngine::global().store() {
            store.flush()?;
        }
        Ok(NetServeOutcome { sessions, requests: requests.load(Ordering::Relaxed) })
    }
}

/// Drive one connection's session to completion. Returns the commands it
/// served; client-side I/O failures end the session quietly (there is no
/// one left to report them to).
fn handle_connection(
    stream: TcpStream,
    pool: &Pool,
    flag: &Arc<AtomicBool>,
    opts: &ServeOptions,
) -> usize {
    if stream.set_read_timeout(opts.read_timeout).is_err() {
        return 0;
    }
    // request/response over short lines: latency beats batching
    let _ = stream.set_nodelay(true);
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return 0,
    };
    let mut writer = BufWriter::new(stream);
    let mut session = Session::new(pool, Some(Arc::clone(flag)));
    match session.run(reader, &mut writer) {
        Ok(SessionEnd::Timeout) => {
            let _ = writeln!(writer, "timeout");
            let _ = writer.flush();
        }
        Ok(SessionEnd::Eof | SessionEnd::Quit | SessionEnd::Shutdown) => {}
        Err(_) => {}
    }
    session.served
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufRead;

    #[test]
    fn tcp_session_serves_estimates_and_drains_on_shutdown() {
        let srv = NetServer::bind("127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = srv.local_addr();
        let handle = srv.shutdown_handle();
        let t = std::thread::spawn(move || srv.run().unwrap());
        let client = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(client.try_clone().unwrap());
        let mut writer = client;
        writer.write_all(b"estimate ultratrail tc_resnet8\nquit\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("cycles="), "{line}");
        // `quit` closes only this connection
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0);
        handle.shutdown();
        let out = t.join().unwrap();
        assert_eq!(out.sessions, 1);
        assert!(out.requests >= 1, "{out:?}");
    }

    #[test]
    fn connections_past_the_cap_get_a_busy_line() {
        let opts = ServeOptions { max_clients: 0, ..Default::default() };
        let srv = NetServer::bind("127.0.0.1:0", opts).unwrap();
        let addr = srv.local_addr();
        let handle = srv.shutdown_handle();
        let t = std::thread::spawn(move || srv.run().unwrap());
        let client = TcpStream::connect(addr).unwrap();
        let mut line = String::new();
        BufReader::new(client).read_line(&mut line).unwrap();
        assert_eq!(line, "busy\n");
        handle.shutdown();
        let out = t.join().unwrap();
        assert_eq!(out, NetServeOutcome { sessions: 0, requests: 0 });
    }

    #[test]
    fn idle_connections_time_out_with_a_line() {
        let opts = ServeOptions {
            read_timeout: Some(std::time::Duration::from_millis(50)),
            ..Default::default()
        };
        let srv = NetServer::bind("127.0.0.1:0", opts).unwrap();
        let addr = srv.local_addr();
        let handle = srv.shutdown_handle();
        let t = std::thread::spawn(move || srv.run().unwrap());
        let client = TcpStream::connect(addr).unwrap();
        let mut line = String::new();
        // send nothing: the read deadline must end the session for us
        BufReader::new(client).read_line(&mut line).unwrap();
        assert_eq!(line, "timeout\n");
        handle.shutdown();
        t.join().unwrap();
    }
}
