//! The estimation coordinator: request/response types, the worker pool,
//! the design-space-exploration driver (roofline pre-filter through the AOT
//! XLA estimator → accurate AIDG pass), and the line-based request server.

pub mod dse;
pub mod job;
pub mod pool;
pub mod server;

pub use dse::{explore, DsePoint, DseSpec, RooflineBackend};
pub use job::{
    estimate_network, run_request, Arch, ArchSource, DescribedArch, EstimateRequest,
    NetworkEstimate,
};
pub use pool::Pool;
pub use server::{parse_arch, serve};
