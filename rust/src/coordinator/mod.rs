//! The estimation coordinator: request/response types, the generic worker
//! pool, the design-space-exploration driver (roofline pre-filter through
//! the AOT XLA estimator → accurate AIDG pass), and the line-based request
//! server. All estimation paths route through the unified engine
//! ([`crate::engine`]); [`estimate_network`] remains as the uncached
//! reference implementation.

pub mod dse;
pub mod job;
pub mod pool;
pub mod server;

pub use dse::{explore, DsePoint, DseSpec, RooflineBackend};
pub use job::{
    estimate_network, run_request, run_request_pooled, Arch, ArchSource, DescribedArch,
    EstimateRequest, EstimateStats, NetworkEstimate,
};
pub use pool::Pool;
pub use server::{parse_arch, serve, serve_with, ServeOptions};
