//! The estimation coordinator: request/response types, the generic worker
//! pool, the legacy Plasticine DSE shim (the generic explorer lives in
//! [`crate::dse`]), and the line-based request server. All estimation
//! paths route through the unified engine ([`crate::engine`]);
//! [`estimate_network`] remains as the uncached reference implementation.
//!
//! Both sides of a request are spec strings: [`parse_arch`] resolves
//! architectures (builders, `file:<path>` descriptions, inline `@name`
//! registrations) and [`resolve_network`] resolves workloads (zoo names,
//! `net:<path>` descriptions, inline `@name` registrations) — see
//! `docs/serve-protocol.md`.

pub mod dse;
pub mod job;
pub mod net;
pub mod pool;
pub mod server;

pub use dse::{explore, DsePoint, DseSpec, RooflineBackend};
pub use job::{
    estimate_network, resolve_network, run_request, run_request_pooled, Arch, ArchSource,
    DescribedArch, DescribedNet, EstimateRequest, EstimateStats, NetSource, NetworkEstimate,
};
pub use net::{NetServeOutcome, NetServer, ShutdownHandle};
pub use pool::Pool;
pub use server::{parse_arch, serve, serve_with, ServeOptions};
