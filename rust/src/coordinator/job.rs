//! Estimation jobs and results — the coordinator's request/response types.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Context as _;

use crate::acadl::text::{compile::CompiledArch, ArchRegistry};
use crate::accel::{
    Gemmini, GemminiConfig, Plasticine, PlasticineConfig, Systolic, SystolicConfig, UltraTrail,
    UltraTrailConfig,
};
use crate::aidg::{estimate_layer, FixedPointConfig, LayerEstimate};
use crate::dnn::text::NetRegistry;
use crate::dnn::Network;
use crate::mapping::{
    gemm_tile::GemmTileMapper, plasticine_map::PlasticineMapper, scalar::ScalarMapper,
    tensor_op::TensorOpMapper, MappedLayer, Mapper,
};
use crate::Result;

/// Where a described architecture's source text lives.
#[derive(Debug, Clone)]
pub enum ArchSource {
    /// Read (and re-read per request — the registry dedupes unchanged
    /// content) from a description file.
    File(PathBuf),
    /// Inline source, e.g. registered through the server's `describe`
    /// command.
    Inline {
        /// Diagnostic label (e.g. `@myarch`).
        label: String,
        /// The description text.
        text: Arc<str>,
    },
}

/// An architecture defined by a textual ACADL description instead of a
/// hardcoded builder.
#[derive(Debug, Clone)]
pub struct DescribedArch {
    /// Where the description text comes from.
    pub source: ArchSource,
}

impl DescribedArch {
    /// A description read from `path` on every resolution (content-deduped
    /// by the global registry).
    pub fn file(path: impl Into<PathBuf>) -> Self {
        Self { source: ArchSource::File(path.into()) }
    }

    /// An inline description labeled `label` for diagnostics.
    pub fn inline(label: impl Into<String>, text: impl Into<Arc<str>>) -> Self {
        Self { source: ArchSource::Inline { label: label.into(), text: text.into() } }
    }

    /// Diagnostic label: the file path or the inline registration name.
    pub fn label(&self) -> String {
        match &self.source {
            ArchSource::File(p) => p.display().to_string(),
            ArchSource::Inline { label, .. } => label.clone(),
        }
    }

    /// Compile (or fetch from the global [`ArchRegistry`] cache) the
    /// description's model.
    pub fn model(&self) -> Result<Arc<CompiledArch>> {
        match &self.source {
            ArchSource::File(p) => {
                let text = std::fs::read_to_string(p).with_context(|| {
                    format!("reading architecture description {}", p.display())
                })?;
                ArchRegistry::global().get_or_compile(&text, &p.display().to_string())
            }
            ArchSource::Inline { label, text } => {
                ArchRegistry::global().get_or_compile(text, label)
            }
        }
    }
}

/// Where a described network's source text lives (the workload-side
/// sibling of [`ArchSource`]).
#[derive(Debug, Clone)]
pub enum NetSource {
    /// Read (and re-read per request — the registry dedupes unchanged
    /// content) from a description file.
    File(PathBuf),
    /// Inline source, e.g. registered through the server's
    /// `network describe` command.
    Inline {
        /// Diagnostic label (e.g. `@mynet`).
        label: String,
        /// The description text.
        text: Arc<str>,
    },
}

/// A DNN workload defined by a textual network description instead of a
/// hardcoded [`crate::dnn::zoo`] builder.
#[derive(Debug, Clone)]
pub struct DescribedNet {
    /// Where the description text comes from.
    pub source: NetSource,
}

impl DescribedNet {
    /// A description read from `path` on every resolution (content-deduped
    /// by the global registry).
    pub fn file(path: impl Into<PathBuf>) -> Self {
        Self { source: NetSource::File(path.into()) }
    }

    /// An inline description labeled `label` for diagnostics.
    pub fn inline(label: impl Into<String>, text: impl Into<Arc<str>>) -> Self {
        Self { source: NetSource::Inline { label: label.into(), text: text.into() } }
    }

    /// Diagnostic label: the file path or the inline registration name.
    pub fn label(&self) -> String {
        match &self.source {
            NetSource::File(p) => p.display().to_string(),
            NetSource::Inline { label, .. } => label.clone(),
        }
    }

    /// Compile (or fetch from the global [`NetRegistry`] cache) the
    /// described network.
    pub fn network(&self) -> Result<Arc<Network>> {
        match &self.source {
            NetSource::File(p) => {
                let text = std::fs::read_to_string(p).with_context(|| {
                    format!("reading network description {}", p.display())
                })?;
                NetRegistry::global().get_or_compile(&text, &p.display().to_string())
            }
            NetSource::Inline { label, text } => {
                NetRegistry::global().get_or_compile(text, label)
            }
        }
    }
}

/// Resolve a network spec string: a [`crate::dnn::zoo`] name or
/// `net:<path>` pointing at a textual network description (`net/*.toml`).
/// Inline `@<name>` registrations exist only inside a serve session and
/// are resolved there.
pub fn resolve_network(spec: &str) -> Result<Arc<Network>> {
    if let Some(path) = spec.strip_prefix("net:") {
        if path.is_empty() {
            anyhow::bail!("net: spec needs a path, e.g. net:net/tc_resnet8.toml");
        }
        return DescribedNet::file(path).network();
    }
    crate::dnn::zoo::by_name(spec).map(Arc::new).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown network {spec:?} (zoo: {}; or net:<path> for a description file)",
            crate::dnn::zoo::all_names().join("|")
        )
    })
}

/// Which accelerator model to instantiate.
#[derive(Debug, Clone)]
pub enum Arch {
    /// Weight-stationary systolic array (scalar mapper).
    Systolic(SystolicConfig),
    /// UltraTrail fused-tensor model.
    UltraTrail(UltraTrailConfig),
    /// Gemmini tiled-GEMM model.
    Gemmini(GemminiConfig),
    /// Plasticine-derived grid.
    Plasticine(PlasticineConfig),
    /// Compiled from a textual ACADL description ([`crate::acadl::text`]).
    Described(DescribedArch),
}

impl Arch {
    /// Display name (e.g. `gemmini16x16`).
    pub fn name(&self) -> String {
        match self {
            Arch::Systolic(c) => format!("systolic{}x{}", c.rows, c.cols),
            Arch::UltraTrail(c) => format!("ultratrail{0}x{0}", c.array_dim),
            Arch::Gemmini(c) => format!("gemmini{0}x{0}", c.dim),
            Arch::Plasticine(c) => format!("plasticine{}x{}t{}", c.rows, c.cols, c.tile),
            Arch::Described(d) => match &d.source {
                ArchSource::File(p) => p
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| d.label()),
                ArchSource::Inline { label, .. } => label.clone(),
            },
        }
    }

    /// Instantiate the model + mapper pair.
    pub fn mapper(&self) -> Result<Box<dyn Mapper + Send + Sync>> {
        Ok(match self {
            Arch::Systolic(c) => Box::new(ScalarMapper::new(Arc::new(Systolic::new(*c)?))),
            Arch::UltraTrail(c) => {
                Box::new(TensorOpMapper::new(Arc::new(UltraTrail::new(*c)?)))
            }
            Arch::Gemmini(c) => Box::new(GemmTileMapper::new(Arc::new(Gemmini::new(*c)?))),
            Arch::Plasticine(c) => {
                Box::new(PlasticineMapper::new(Arc::new(Plasticine::new(*c)?)))
            }
            Arch::Described(d) => d.model()?.model.mapper(),
        })
    }
}

/// One network-on-architecture estimation request.
#[derive(Debug, Clone)]
pub struct EstimateRequest {
    /// The accelerator to estimate on.
    pub arch: Arch,
    /// Network spec ([`resolve_network`]): a zoo name or `net:<path>`.
    pub network: String,
    /// Fixed-point estimator configuration.
    pub fp: FixedPointConfig,
}

/// Per-layer outcome within a network estimate.
#[derive(Debug, Clone)]
pub struct LayerOutcome {
    /// The layer's name.
    pub layer_name: String,
    /// None for layers fused into their predecessor (zero cycles).
    pub estimate: Option<Vec<LayerEstimate>>,
}

impl LayerOutcome {
    /// Layer cycles (0 when fused).
    pub fn cycles(&self) -> u64 {
        self.estimate
            .as_ref()
            .map(|es| es.iter().map(|e| e.cycles).sum())
            .unwrap_or(0)
    }

    /// Iterations evaluated across the layer's kernels.
    pub fn evaluated_iters(&self) -> u64 {
        self.estimate
            .as_ref()
            .map(|es| es.iter().map(|e| e.evaluated_iters).sum())
            .unwrap_or(0)
    }

    /// Total loop iterations across the layer's kernels.
    pub fn total_iters(&self) -> u64 {
        self.estimate.as_ref().map(|es| es.iter().map(|e| e.k).sum()).unwrap_or(0)
    }

    /// Total instructions across the layer's kernels.
    pub fn total_insts(&self) -> u64 {
        self.estimate
            .as_ref()
            .map(|es| es.iter().map(|e| e.total_insts()).sum())
            .unwrap_or(0)
    }

    /// Peak tracked evaluator state across the layer's kernels.
    pub fn peak_state_bytes(&self) -> u64 {
        self.estimate
            .as_ref()
            .map(|es| es.iter().map(|e| e.peak_state_bytes).max().unwrap_or(0))
            .unwrap_or(0)
    }

    /// Calibrated layer cycles, when a calibration model stamped every
    /// kernel of this layer (`None` for fused layers and uncalibrated
    /// estimates). Kernels missing a stamp fall back to their raw cycles.
    pub fn calibrated_cycles(&self) -> Option<u64> {
        let es = self.estimate.as_ref()?;
        if es.iter().all(|e| e.calibrated_cycles.is_none()) {
            return None;
        }
        Some(es.iter().map(|e| e.calibrated_cycles.unwrap_or(e.cycles)).sum())
    }

    /// Summed `[ci_lo, ci_hi]` confidence bounds across the layer's
    /// kernels, under the same presence rule as [`Self::calibrated_cycles`].
    pub fn ci_bounds(&self) -> Option<(u64, u64)> {
        let es = self.estimate.as_ref()?;
        if es.iter().all(|e| e.ci_lo.is_none()) {
            return None;
        }
        let lo = es.iter().map(|e| e.ci_lo.unwrap_or(e.cycles)).sum();
        let hi = es.iter().map(|e| e.ci_hi.unwrap_or(e.cycles)).sum();
        Some((lo, hi))
    }
}

/// Kernel-level accounting of how a network estimate was assembled by the
/// unified engine ([`crate::engine`]). The uncached reference path
/// ([`estimate_network`]) evaluates everything, so it reports
/// `evaluated == unique_kernels == total_kernels` and zero hits/dedup.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EstimateStats {
    /// Kernel slots in the request (every kernel of every non-fused layer).
    pub total_kernels: u64,
    /// Distinct kernel fingerprints among those slots.
    pub unique_kernels: u64,
    /// Slots served from the cross-request estimate cache.
    pub cache_hits: u64,
    /// Slots reusing an identical kernel evaluated earlier in this request.
    pub deduped: u64,
    /// Kernels actually evaluated through the AIDG.
    pub evaluated: u64,
}

impl EstimateStats {
    /// Account one kernel slot by its estimate's provenance.
    pub fn count(&mut self, p: crate::aidg::Provenance) {
        self.total_kernels += 1;
        match p {
            crate::aidg::Provenance::Computed => self.evaluated += 1,
            crate::aidg::Provenance::Deduped => self.deduped += 1,
            crate::aidg::Provenance::CacheHit => self.cache_hits += 1,
        }
    }
}

/// Whole-network estimation result (eq. 14: `T̂ = Σ Δt̂_i`).
#[derive(Debug, Clone)]
pub struct NetworkEstimate {
    /// Workload name.
    pub network: String,
    /// Architecture name.
    pub arch: String,
    /// Per-layer outcomes in network order.
    pub layers: Vec<LayerOutcome>,
    /// Wall time of the estimate.
    pub runtime: Duration,
    /// How the engine assembled this estimate (hit/miss/dedup accounting).
    pub stats: EstimateStats,
}

impl NetworkEstimate {
    /// Whole-network cycles (eq. 14).
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles()).sum()
    }

    /// Total loop iterations.
    pub fn total_iters(&self) -> u64 {
        self.layers.iter().map(|l| l.total_iters()).sum()
    }

    /// Iterations actually evaluated.
    pub fn evaluated_iters(&self) -> u64 {
        self.layers.iter().map(|l| l.evaluated_iters()).sum()
    }

    /// Total instructions.
    pub fn total_insts(&self) -> u64 {
        self.layers.iter().map(|l| l.total_insts()).sum()
    }

    /// Per-layer cycle vector (fused layers are 0), for MAPE computations.
    pub fn layer_cycles(&self) -> Vec<f64> {
        self.layers.iter().map(|l| l.cycles() as f64).collect()
    }

    /// Calibrated whole-network cycles (`None` when no layer was stamped
    /// by a calibration model; fused layers contribute their raw 0).
    pub fn calibrated_cycles(&self) -> Option<u64> {
        if self.layers.iter().all(|l| l.calibrated_cycles().is_none()) {
            return None;
        }
        Some(self.layers.iter().map(|l| l.calibrated_cycles().unwrap_or(l.cycles())).sum())
    }

    /// Summed whole-network `[ci_lo, ci_hi]` bounds, under the same
    /// presence rule as [`Self::calibrated_cycles`].
    pub fn ci_bounds(&self) -> Option<(u64, u64)> {
        if self.layers.iter().all(|l| l.ci_bounds().is_none()) {
            return None;
        }
        let mut lo = 0u64;
        let mut hi = 0u64;
        for l in &self.layers {
            let (a, b) = l.ci_bounds().unwrap_or_else(|| (l.cycles(), l.cycles()));
            lo += a;
            hi += b;
        }
        Some((lo, hi))
    }
}

/// Estimate a whole network on a mapper (AIDG fixed-point per layer; a
/// layer's latency is the sum of its kernels' estimates — §6.3 applied per
/// uniform loop kernel).
///
/// This is the **uncached reference path**: every kernel is evaluated,
/// nothing is reused. The production hot path is the unified engine
/// ([`crate::engine::EstimationEngine`]), which `run_request`, the serve
/// loop, and the CLI route through; `rust/tests/engine_cache.rs` pins the
/// two cycle-identical.
pub fn estimate_network(
    mapper: &(impl Mapper + ?Sized),
    net: &Network,
    fp: &FixedPointConfig,
) -> Result<NetworkEstimate> {
    let t0 = std::time::Instant::now();
    let mapped: Vec<MappedLayer> = mapper.map_network(net)?;
    let d = mapper.diagram();
    let mut layers = Vec::with_capacity(mapped.len());
    let mut kernels = 0u64;
    for ml in &mapped {
        if ml.fused {
            layers.push(LayerOutcome { layer_name: ml.layer_name.clone(), estimate: None });
            continue;
        }
        let mut ests = Vec::with_capacity(ml.kernels.len());
        for k in &ml.kernels {
            ests.push(estimate_layer(d, k, fp)?);
            kernels += 1;
        }
        layers.push(LayerOutcome { layer_name: ml.layer_name.clone(), estimate: Some(ests) });
    }
    Ok(NetworkEstimate {
        network: net.name.clone(),
        arch: d.name.clone(),
        layers,
        runtime: t0.elapsed(),
        stats: EstimateStats {
            total_kernels: kernels,
            unique_kernels: kernels,
            evaluated: kernels,
            ..Default::default()
        },
    })
}

/// Run one request end-to-end (build arch, map, estimate) through the
/// global [`EstimationEngine`](crate::engine::EstimationEngine) — repeated
/// kernel shapes within the network and across requests are priced once.
pub fn run_request(req: &EstimateRequest) -> Result<NetworkEstimate> {
    let net = resolve_network(&req.network)?;
    crate::engine::EstimationEngine::global().estimate_network(&req.arch, &net, &req.fp)
}

/// [`run_request`] with cache misses fanned out at kernel granularity over
/// `pool` (the serve loop's and the CLI's hot path). Must be called from
/// outside `pool`'s own workers — see
/// [`EstimationEngine::estimate_network_pooled`](crate::engine::EstimationEngine::estimate_network_pooled).
pub fn run_request_pooled(
    req: &EstimateRequest,
    pool: &super::pool::Pool,
) -> Result<NetworkEstimate> {
    let net = resolve_network(&req.network)?;
    crate::engine::EstimationEngine::global()
        .estimate_network_pooled(&req.arch, &net, &req.fp, pool)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ultratrail_request_runs() {
        let req = EstimateRequest {
            arch: Arch::UltraTrail(UltraTrailConfig::default()),
            network: "tc_resnet8".into(),
            fp: FixedPointConfig::default(),
        };
        let e = run_request(&req).unwrap();
        assert_eq!(e.layers.len(), 22);
        assert!(e.total_cycles() > 10_000, "cycles {}", e.total_cycles());
        assert!(e.total_cycles() < 100_000, "cycles {}", e.total_cycles());
    }

    #[test]
    fn network_specs_resolve() {
        assert_eq!(resolve_network("tc_resnet8").unwrap().num_layers(), 22);
        let described = resolve_network("net:net/tc_resnet8.toml").unwrap();
        assert_eq!(described.name, "tc_resnet8");
        assert!(resolve_network("net:").is_err());
        assert!(resolve_network("net:/no/such/file.toml").is_err());
        assert!(resolve_network("vgg").is_err());
    }

    #[test]
    fn unknown_network_fails() {
        let req = EstimateRequest {
            arch: Arch::UltraTrail(UltraTrailConfig::default()),
            network: "vgg".into(),
            fp: FixedPointConfig::default(),
        };
        assert!(run_request(&req).is_err());
    }

    #[test]
    fn systolic_estimate_has_sensible_iteration_reduction() {
        let req = EstimateRequest {
            arch: Arch::Systolic(SystolicConfig::new(2, 2)),
            network: "tc_resnet8".into(),
            fp: FixedPointConfig::default(),
        };
        let e = run_request(&req).unwrap();
        // fixed-point evaluation must evaluate far fewer iterations than k
        assert!(e.evaluated_iters() < e.total_iters() / 10,
            "evaluated {} of {}", e.evaluated_iters(), e.total_iters());
        assert!(e.total_cycles() > 0);
    }
}
