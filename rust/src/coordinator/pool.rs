//! Worker pool: a generic work-item pool over std threads (tokio is not
//! vendored in this offline image — the workload is CPU-bound, so a plain
//! thread pool over an MPMC queue is the right tool anyway; see DESIGN.md).
//!
//! The queue carries boxed closures, not whole estimation requests: the
//! unified engine ([`crate::engine`]) fans a single network estimate out at
//! *kernel* granularity via [`Pool::spawn`], so one large request no longer
//! pins a single worker. The typed request API ([`Pool::submit_all`] /
//! [`Pool::run_all`]) is a thin layer over the same queue.
//!
//! Failure semantics: a panicking work item is caught
//! (`std::panic::catch_unwind`) and the worker keeps serving; submitting to
//! a shut-down pool or losing a result both surface as `Err` values — the
//! pool never panics the caller.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::Result;

use super::job::{run_request, EstimateRequest, NetworkEstimate};

/// A queued unit of work.
type WorkItem = Box<dyn FnOnce() + Send + 'static>;

/// Shared MPMC queue (Mutex + Condvar; no crossbeam offline).
struct Queue {
    jobs: Mutex<(VecDeque<WorkItem>, bool)>, // (queue, closed)
    cv: Condvar,
}

impl Queue {
    fn push(&self, j: WorkItem) -> Result<()> {
        let mut g = self.jobs.lock().unwrap();
        if g.1 {
            anyhow::bail!("worker pool is shut down");
        }
        g.0.push_back(j);
        self.cv.notify_one();
        Ok(())
    }

    fn pop(&self) -> Option<WorkItem> {
        let mut g = self.jobs.lock().unwrap();
        loop {
            if let Some(j) = g.0.pop_front() {
                return Some(j);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn close(&self) {
        self.jobs.lock().unwrap().1 = true;
        self.cv.notify_all();
    }
}

/// A pool of estimation workers.
pub struct Pool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn `n` workers (defaults to available parallelism when 0).
    pub fn new(n: usize) -> Self {
        let n = if n == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        } else {
            n
        };
        let queue = Arc::new(Queue {
            jobs: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("acadl-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = q.pop() {
                            // a panicking item must not take the worker (and
                            // with it every queued job) down; the submitter
                            // observes the failure as a missing result
                            let _ = catch_unwind(AssertUnwindSafe(job));
                        }
                    })
                    .expect("spawning worker")
            })
            .collect();
        Self { queue, workers }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one work item. Fails (instead of panicking) when the pool
    /// has been shut down.
    ///
    /// Each job's lifecycle is observable via [`crate::obs`]: the
    /// `pool.queue_depth` gauge rises on enqueue and falls on pickup, the
    /// `pool.inflight` gauge covers execution (panic-safe), and — when
    /// tracing is enabled — the job runs under a `pool.job` span whose
    /// parent is the span that called `spawn`, with the queue wait recorded
    /// as a `queued_ns` argument. Spans opened inside the job nest under
    /// `pool.job`, so cross-thread traces keep their request structure.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        // capture the submitter's span context and enqueue time *now*; the
        // wrapper re-parents the job's span on whichever worker runs it
        let parent = crate::obs::current_span_id();
        let enq_ns = if crate::obs::enabled() { crate::obs::now_ns() } else { 0 };
        crate::obs::gauge::POOL_QUEUE_DEPTH.add(1);
        let queued = self.queue.push(Box::new(move || {
            crate::obs::gauge::POOL_QUEUE_DEPTH.add(-1);
            let _inflight = crate::obs::gauge::POOL_INFLIGHT.raii();
            let mut sp = crate::obs::span_with_parent("pool.job", parent);
            if enq_ns != 0 {
                sp.arg("queued_ns", crate::obs::now_ns().saturating_sub(enq_ns));
            }
            job();
        }));
        if queued.is_err() {
            // never enqueued: the wrapper's decrement will not run
            crate::obs::gauge::POOL_QUEUE_DEPTH.add(-1);
        }
        queued
    }

    /// Shut the pool down: queued items still run, new submissions fail.
    /// (Also invoked by `Drop`.)
    pub fn close(&self) {
        self.queue.close();
    }

    /// Submit a batch of requests; returns a receiver yielding
    /// `(submission index, result)` in completion order. Requests that
    /// cannot be queued (pool shut down) yield an `Err` result immediately.
    pub fn submit_all(
        &self,
        reqs: Vec<EstimateRequest>,
    ) -> Receiver<(usize, Result<NetworkEstimate>)> {
        let (tx, rx) = channel();
        for (id, req) in reqs.into_iter().enumerate() {
            let txc = tx.clone();
            let queued = self.spawn(move || {
                let r = run_request(&req);
                // receiver may be gone if the caller bailed
                let _ = txc.send((id, r));
            });
            if let Err(e) = queued {
                let _ = tx.send((id, Err(e)));
            }
        }
        rx
    }

    /// Submit and wait for everything, results in submission order. A
    /// request whose result is lost (its worker died mid-job or the pool
    /// shut down underneath it) comes back as an `Err` entry — never a
    /// panic.
    pub fn run_all(&self, reqs: Vec<EstimateRequest>) -> Vec<Result<NetworkEstimate>> {
        let n = reqs.len();
        let rx = self.submit_all(reqs);
        let mut out: Vec<Option<Result<NetworkEstimate>>> = (0..n).map(|_| None).collect();
        let mut got = 0;
        while got < n {
            match rx.recv() {
                Ok((id, r)) => {
                    out[id] = Some(r);
                    got += 1;
                }
                Err(_) => break, // every sender dropped without delivering
            }
        }
        out.into_iter()
            .map(|o| {
                o.unwrap_or_else(|| {
                    Err(anyhow::anyhow!(
                        "worker pool hung up before returning a result \
                         (worker died or pool shut down)"
                    ))
                })
            })
            .collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{SystolicConfig, UltraTrailConfig};
    use crate::aidg::FixedPointConfig;
    use crate::coordinator::job::Arch;

    fn req(arch: Arch) -> EstimateRequest {
        EstimateRequest { arch, network: "tc_resnet8".into(), fp: FixedPointConfig::default() }
    }

    #[test]
    fn pool_runs_jobs_in_parallel_and_in_order() {
        let pool = Pool::new(4);
        let reqs: Vec<EstimateRequest> = (0..6)
            .map(|i| {
                if i % 2 == 0 {
                    req(Arch::UltraTrail(UltraTrailConfig::default()))
                } else {
                    req(Arch::Systolic(SystolicConfig::new(2, 2)))
                }
            })
            .collect();
        let results = pool.run_all(reqs);
        assert_eq!(results.len(), 6);
        for (i, r) in results.iter().enumerate() {
            let e = r.as_ref().unwrap();
            if i % 2 == 0 {
                assert!(e.arch.starts_with("ultratrail"), "{i}: {}", e.arch);
            } else {
                assert!(e.arch.starts_with("systolic"), "{i}: {}", e.arch);
            }
        }
        // identical requests give identical results (determinism across
        // threads)
        assert_eq!(results[0].as_ref().unwrap().total_cycles(),
                   results[2].as_ref().unwrap().total_cycles());
    }

    #[test]
    fn errors_are_reported_not_panicked() {
        let pool = Pool::new(2);
        let results = pool.run_all(vec![EstimateRequest {
            arch: Arch::UltraTrail(UltraTrailConfig::default()),
            network: "alexnet".into(), // 2D: unmappable on UltraTrail
            fp: FixedPointConfig::default(),
        }]);
        assert!(results[0].is_err());
    }

    #[test]
    fn closed_pool_surfaces_errors_not_panics() {
        let pool = Pool::new(1);
        pool.close();
        assert!(pool.spawn(|| {}).is_err());
        let results = pool.run_all(vec![req(Arch::Systolic(SystolicConfig::new(2, 2)))]);
        assert_eq!(results.len(), 1);
        assert!(results[0].is_err());
    }

    #[test]
    fn panicking_job_does_not_kill_the_pool() {
        // the intentional panic below prints one backtrace line in the test
        // output; swallowing it would mean swapping the process-global
        // panic hook under concurrently running tests, which is worse
        let pool = Pool::new(1);
        pool.spawn(|| panic!("intentional test panic (caught by the pool)")).unwrap();
        // the single worker must survive to serve the real request
        let results = pool.run_all(vec![req(Arch::Systolic(SystolicConfig::new(2, 2)))]);
        assert!(results[0].is_ok(), "{:?}", results[0].as_ref().err());
    }

    #[test]
    fn spawn_runs_generic_work_items() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.spawn(move || {
                c.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(());
            })
            .unwrap();
        }
        drop(tx);
        assert_eq!(rx.iter().count(), 32);
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }
}
