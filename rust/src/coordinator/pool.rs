//! Worker pool: estimation jobs fan out over std threads (tokio is not
//! vendored in this offline image — the workload is CPU-bound, so a plain
//! thread pool over an MPMC queue is the right tool anyway; see DESIGN.md).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::Result;

use super::job::{run_request, EstimateRequest, NetworkEstimate};

type Job = (usize, EstimateRequest, Sender<(usize, Result<NetworkEstimate>)>);

/// Shared MPMC queue (Mutex + Condvar; no crossbeam offline).
struct Queue {
    jobs: Mutex<(std::collections::VecDeque<Job>, bool)>, // (queue, closed)
    cv: Condvar,
}

impl Queue {
    fn push(&self, j: Job) {
        let mut g = self.jobs.lock().unwrap();
        assert!(!g.1, "pool already shut down");
        g.0.push_back(j);
        self.cv.notify_one();
    }

    fn pop(&self) -> Option<Job> {
        let mut g = self.jobs.lock().unwrap();
        loop {
            if let Some(j) = g.0.pop_front() {
                return Some(j);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn close(&self) {
        self.jobs.lock().unwrap().1 = true;
        self.cv.notify_all();
    }
}

/// A pool of estimation workers.
pub struct Pool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    next_id: usize,
}

impl Pool {
    /// Spawn `n` workers (defaults to available parallelism when 0).
    pub fn new(n: usize) -> Self {
        let n = if n == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        } else {
            n
        };
        let queue = Arc::new(Queue {
            jobs: Mutex::new((std::collections::VecDeque::new(), false)),
            cv: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("acadl-worker-{i}"))
                    .spawn(move || {
                        while let Some((id, req, tx)) = q.pop() {
                            let r = run_request(&req);
                            // receiver may be gone if the caller bailed
                            let _ = tx.send((id, r));
                        }
                    })
                    .expect("spawning worker")
            })
            .collect();
        Self { queue, workers, next_id: 0 }
    }

    /// Submit a batch of requests; returns a receiver yielding
    /// `(submission index, result)` in completion order.
    pub fn submit_all(
        &mut self,
        reqs: Vec<EstimateRequest>,
    ) -> Receiver<(usize, Result<NetworkEstimate>)> {
        let (tx, rx) = channel();
        for req in reqs {
            let id = self.next_id;
            self.next_id += 1;
            self.queue.push((id, req, tx.clone()));
        }
        rx
    }

    /// Submit and wait for everything, results in submission order.
    pub fn run_all(&mut self, reqs: Vec<EstimateRequest>) -> Vec<Result<NetworkEstimate>> {
        let n = reqs.len();
        let base = self.next_id;
        let rx = self.submit_all(reqs);
        let mut out: Vec<Option<Result<NetworkEstimate>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (id, r) = rx.recv().expect("worker pool hung up");
            out[id - base] = Some(r);
        }
        out.into_iter().map(|o| o.expect("missing result")).collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{SystolicConfig, UltraTrailConfig};
    use crate::aidg::FixedPointConfig;
    use crate::coordinator::job::Arch;

    #[test]
    fn pool_runs_jobs_in_parallel_and_in_order() {
        let mut pool = Pool::new(4);
        let reqs: Vec<EstimateRequest> = (0..6)
            .map(|i| EstimateRequest {
                arch: if i % 2 == 0 {
                    Arch::UltraTrail(UltraTrailConfig::default())
                } else {
                    Arch::Systolic(SystolicConfig::new(2, 2))
                },
                network: "tc_resnet8".into(),
                fp: FixedPointConfig::default(),
            })
            .collect();
        let results = pool.run_all(reqs);
        assert_eq!(results.len(), 6);
        for (i, r) in results.iter().enumerate() {
            let e = r.as_ref().unwrap();
            if i % 2 == 0 {
                assert!(e.arch.starts_with("ultratrail"), "{i}: {}", e.arch);
            } else {
                assert!(e.arch.starts_with("systolic"), "{i}: {}", e.arch);
            }
        }
        // identical requests give identical results (determinism across
        // threads)
        assert_eq!(results[0].as_ref().unwrap().total_cycles(),
                   results[2].as_ref().unwrap().total_cycles());
    }

    #[test]
    fn errors_are_reported_not_panicked() {
        let mut pool = Pool::new(2);
        let results = pool.run_all(vec![EstimateRequest {
            arch: Arch::UltraTrail(UltraTrailConfig::default()),
            network: "alexnet".into(), // 2D: unmappable on UltraTrail
            fp: FixedPointConfig::default(),
        }]);
        assert!(results[0].is_err());
    }
}
