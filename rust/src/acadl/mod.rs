//! The Abstract Computer Architecture Description Language (ACADL).
//!
//! ACADL models computer architectures as object diagrams of a small set of
//! classes (paper §4, Fig. 2). Architectures are *instruction-centric*: any
//! architectural state change is triggered by an instruction propagating
//! from the instruction memory through pipeline stages to a functional unit.
//! Latencies are attached to the modules an instruction occupies, either as
//! integers or as expressions over the instruction's immediates
//! ([`latency::Latency`]), which is what lets a single diagram span
//! abstraction levels from scalar `mac`s to fused `conv_ext` tensor ops.

pub mod diagram;
pub mod latency;
pub mod object;
pub mod text;

pub use diagram::{Diagram, FetchConfig, Route};
pub use latency::{Expr, Latency};
pub use object::{Lock, Object, ObjectKind};
