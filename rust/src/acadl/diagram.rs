//! The ACADL object diagram: instantiated objects plus their associations
//! (forward, containment, register/memory access), a fluent builder, and
//! instruction routing.
//!
//! Routing computes the order `o⃗(i)` of objects an instruction passes
//! through (paper §6.1): merged instruction-memory fetch → instruction fetch
//! stage → (intermediate pipeline stages) → the first functional unit that
//! supports the operation *and* has access to all read/write registers and
//! memories → memory objects for reads → `writeBack` (if the instruction
//! reads memory) → memory objects for writes.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::acadl::latency::Latency;
use crate::acadl::object::{Lock, Object, ObjectKind};
use crate::ids::{Addr, Interner, ObjId, OpId, RegId};
use crate::isa::Instruction;

/// Fetch-path configuration extracted from the instruction memory, the
/// InstructionMemoryAccessUnit, and the InstructionFetchStage.
#[derive(Debug, Clone, Copy)]
pub struct FetchConfig {
    /// Merged fetch node object (the instruction memory).
    pub instr_mem: ObjId,
    /// Instructions fetched per transaction (instruction memory port width).
    pub port_width: u32,
    /// Instruction memory read latency (fixed: instruction fetches carry no
    /// immediates).
    pub read_latency: u64,
    /// The InstructionFetchStage object.
    pub fetch_stage: ObjId,
    /// IFS residence latency.
    pub ifs_latency: u64,
    /// Issue buffer capacity (max instructions entering/issuing per cycle).
    pub issue_buffer_size: u32,
}

/// The route of one instruction through the diagram.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Route {
    /// Intermediate pipeline stages between the IFS and the FU (often none).
    pub stages: Vec<ObjId>,
    /// The functional unit that processes the instruction.
    pub fu: ObjId,
    /// Memory objects serving the instruction's read addresses (deduped, in
    /// first-occurrence order).
    pub read_mems: Vec<ObjId>,
    /// Memory objects serving the write addresses.
    pub write_mems: Vec<ObjId>,
    /// Whether a `writeBack` node follows the read-memory nodes.
    pub has_writeback: bool,
}

impl Route {
    /// Number of AIDG nodes this route contributes after the merged fetch
    /// node and the IFS node.
    pub fn tail_len(&self) -> usize {
        self.stages.len()
            + 1
            + self.read_mems.len()
            + usize::from(self.has_writeback)
            + self.write_mems.len()
    }
}

#[derive(Debug, PartialEq, Eq, Hash)]
struct RouteKey {
    op: OpId,
    read_regs: Vec<RegId>,
    write_regs: Vec<RegId>,
    read_mems: Vec<ObjId>,
    write_mems: Vec<ObjId>,
}

/// An accelerator architecture modeled in ACADL.
#[derive(Debug)]
pub struct Diagram {
    /// Architecture name.
    pub name: String,
    objects: Vec<Object>,
    ops: Interner,
    regs: Interner,

    // associations
    forward: Vec<Vec<ObjId>>,   // pipeline forwarding graph
    contains: Vec<Vec<ObjId>>,  // ExecuteStage -> FUs
    fu_read_rf: Vec<Vec<ObjId>>,
    fu_write_rf: Vec<Vec<ObjId>>,
    fu_read_mem: Vec<Vec<ObjId>>,
    fu_write_mem: Vec<Vec<ObjId>>,

    // derived (finalize)
    reg_home: Vec<ObjId>,                  // RegId -> RegisterFile
    op_fus: HashMap<OpId, Vec<ObjId>>,     // candidates per op
    locks: Vec<Lock>,                      // per object
    addr_map: Vec<(Addr, Addr, ObjId)>,    // sorted address ranges
    stage_path: Vec<Vec<ObjId>>,           // per-FU: stages IFS -> FU's ES
    fetch: Option<FetchConfig>,
    writeback: Option<ObjId>,
    finalized: bool,

    route_cache: Mutex<HashMap<RouteKey, std::sync::Arc<Route>>>,
}

impl Diagram {
    /// An empty, unfinalized diagram named `name`.
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            objects: Vec::new(),
            ops: Interner::new(),
            regs: Interner::new(),
            forward: Vec::new(),
            contains: Vec::new(),
            fu_read_rf: Vec::new(),
            fu_write_rf: Vec::new(),
            fu_read_mem: Vec::new(),
            fu_write_mem: Vec::new(),
            reg_home: Vec::new(),
            op_fus: HashMap::new(),
            locks: Vec::new(),
            addr_map: Vec::new(),
            stage_path: Vec::new(),
            fetch: None,
            writeback: None,
            finalized: false,
            route_cache: Mutex::new(HashMap::new()),
        }
    }

    // ---- interning ------------------------------------------------------

    /// Intern an operation mnemonic.
    pub fn op(&mut self, name: &str) -> OpId {
        OpId(self.ops.intern(name))
    }

    /// Resolve an op id to its mnemonic.
    pub fn op_name(&self, op: OpId) -> &str {
        self.ops.name(op.0)
    }

    /// Look up an already-interned op by mnemonic.
    pub fn lookup_op(&self, name: &str) -> Option<OpId> {
        self.ops.get(name).map(OpId)
    }

    /// Number of interned registers.
    pub fn num_regs(&self) -> usize {
        self.regs.len()
    }

    /// Resolve a register id to its name.
    pub fn reg_name(&self, r: RegId) -> &str {
        self.regs.name(r.0)
    }

    /// Look up an already-interned register by name (used to rebind
    /// description-compiled diagrams to mapper handles).
    pub fn lookup_reg(&self, name: &str) -> Option<RegId> {
        self.regs.get(name).map(RegId)
    }

    /// Look up an object by name (first match; names are unique in
    /// builder- and description-compiled diagrams).
    pub fn lookup_object(&self, name: &str) -> Option<ObjId> {
        self.objects
            .iter()
            .position(|o| o.name == name)
            .map(|i| ObjId(i as u32))
    }

    // Binder-friendly lookups: the `accel::*::from_described` constructors
    // resolve their mapper handles through these, so missing-name errors
    // read uniformly (`what` names the diagram being bound).

    /// [`lookup_op`](Self::lookup_op), erroring when absent.
    pub fn require_op(&self, name: &str, what: &str) -> Result<OpId> {
        self.lookup_op(name).with_context(|| format!("{what} has no op `{name}`"))
    }

    /// [`lookup_reg`](Self::lookup_reg), erroring when absent.
    pub fn require_reg(&self, name: &str, what: &str) -> Result<RegId> {
        self.lookup_reg(name).with_context(|| format!("{what} has no register `{name}`"))
    }

    /// [`lookup_object`](Self::lookup_object) restricted to memories,
    /// erroring when absent or of the wrong kind.
    pub fn require_memory(&self, name: &str, what: &str) -> Result<ObjId> {
        let id = self
            .lookup_object(name)
            .with_context(|| format!("{what} has no memory `{name}`"))?;
        if !self.objects[id.idx()].is_memory() {
            bail!("{what}: object `{name}` must be a memory");
        }
        Ok(id)
    }

    // ---- object construction --------------------------------------------

    fn push(&mut self, name: &str, kind: ObjectKind) -> ObjId {
        let id = ObjId(self.objects.len() as u32);
        self.objects.push(Object { name: name.to_string(), kind });
        self.forward.push(Vec::new());
        self.contains.push(Vec::new());
        self.fu_read_rf.push(Vec::new());
        self.fu_write_rf.push(Vec::new());
        self.fu_read_mem.push(Vec::new());
        self.fu_write_mem.push(Vec::new());
        self.finalized = false;
        id
    }

    /// Add the fetch front-end: instruction memory (+ implicit
    /// InstructionMemoryAccessUnit) and the InstructionFetchStage.
    pub fn add_fetch(
        &mut self,
        imem_name: &str,
        read_latency: u64,
        port_width: u32,
        ifs_name: &str,
        ifs_latency: u64,
        issue_buffer_size: u32,
    ) -> (ObjId, ObjId) {
        assert!(port_width >= 1 && issue_buffer_size >= 1);
        let imem = self.push(
            imem_name,
            ObjectKind::Memory {
                read_latency: Latency::Fixed(read_latency),
                write_latency: Latency::Fixed(0),
                data_width: 32,
                port_width,
                max_concurrent_requests: 1,
                address_ranges: Vec::new(),
            },
        );
        let ifs = self.push(
            ifs_name,
            ObjectKind::InstructionFetchStage {
                latency: Latency::Fixed(ifs_latency),
                issue_buffer_size,
            },
        );
        self.fetch = Some(FetchConfig {
            instr_mem: imem,
            port_width,
            read_latency,
            fetch_stage: ifs,
            ifs_latency,
            issue_buffer_size,
        });
        (imem, ifs)
    }

    /// Add a pipeline stage.
    pub fn add_stage(&mut self, name: &str, latency: impl Into<Latency>) -> ObjId {
        self.push(name, ObjectKind::PipelineStage { latency: latency.into() })
    }

    /// Add an execute stage.
    pub fn add_execute_stage(&mut self, name: &str) -> ObjId {
        self.push(name, ObjectKind::ExecuteStage)
    }

    /// Add a FunctionalUnit contained in `es`, supporting `ops`.
    pub fn add_fu(
        &mut self,
        es: ObjId,
        name: &str,
        latency: Latency,
        ops: &[&str],
    ) -> ObjId {
        let to_process: Vec<OpId> = ops.iter().map(|o| self.op(o)).collect();
        let fu = self.push(name, ObjectKind::FunctionalUnit { latency, to_process });
        self.contains[es.idx()].push(fu);
        fu
    }

    /// Add a RegisterFile with `count` registers named `{prefix}{i}`;
    /// returns their ids.
    pub fn add_regfile(&mut self, name: &str, prefix: &str, count: u32) -> (ObjId, Vec<RegId>) {
        let mut reg_ids = Vec::with_capacity(count as usize);
        for i in 0..count {
            let rid = RegId(self.regs.intern(&format!("{prefix}{i}")));
            reg_ids.push(rid);
        }
        let rf = self.push(
            name,
            ObjectKind::RegisterFile { data_width: 32, regs: reg_ids.clone() },
        );
        (rf, reg_ids)
    }

    /// Add a data memory claiming `[base, base+words)` of the global address
    /// space.
    #[allow(clippy::too_many_arguments)]
    pub fn add_memory(
        &mut self,
        name: &str,
        read_latency: impl Into<Latency>,
        write_latency: impl Into<Latency>,
        port_width: u32,
        max_concurrent_requests: u32,
        base: Addr,
        words: u64,
    ) -> ObjId {
        assert!(port_width >= 1 && max_concurrent_requests >= 1);
        self.push(
            name,
            ObjectKind::Memory {
                read_latency: read_latency.into(),
                write_latency: write_latency.into(),
                data_width: 32,
                port_width,
                max_concurrent_requests,
                address_ranges: vec![(base, base + words)],
            },
        )
    }

    /// Add a further address range `[base, base+words)` to an existing
    /// memory (multi-range memories; overlap against other memories is
    /// validated at `finalize`). Panics when `mem` is not a Memory object.
    pub fn add_memory_range(&mut self, mem: ObjId, base: Addr, words: u64) {
        match &mut self.objects[mem.idx()].kind {
            ObjectKind::Memory { address_ranges, .. } => address_ranges.push((base, base + words)),
            other => panic!("add_memory_range on non-memory object: {other:?}"),
        }
    }

    // ---- associations ----------------------------------------------------

    /// Forward association between pipeline stages / execute stages.
    pub fn forward(&mut self, from: ObjId, to: ObjId) {
        self.forward[from.idx()].push(to);
    }

    /// Register-file read association.
    pub fn fu_reads(&mut self, fu: ObjId, rf: ObjId) {
        self.fu_read_rf[fu.idx()].push(rf);
    }

    /// Register-file write association.
    pub fn fu_writes(&mut self, fu: ObjId, rf: ObjId) {
        self.fu_write_rf[fu.idx()].push(rf);
    }

    /// Memory read association.
    pub fn mem_reads(&mut self, fu: ObjId, mem: ObjId) {
        self.fu_read_mem[fu.idx()].push(mem);
    }

    /// Memory write association.
    pub fn mem_writes(&mut self, fu: ObjId, mem: ObjId) {
        self.fu_write_mem[fu.idx()].push(mem);
    }

    // ---- accessors --------------------------------------------------------

    /// The object behind `id`.
    pub fn object(&self, id: ObjId) -> &Object {
        &self.objects[id.idx()]
    }

    /// Number of objects.
    pub fn num_objects(&self) -> usize {
        self.objects.len()
    }

    /// The fetch front-end (panics when absent).
    pub fn fetch_config(&self) -> &FetchConfig {
        self.fetch.as_ref().expect("diagram has no fetch front-end")
    }

    /// The implicit write-back pseudo-object (panics before `finalize`).
    pub fn writeback_obj(&self) -> ObjId {
        self.writeback.expect("diagram not finalized")
    }

    /// Structural-lock configuration of `id`.
    pub fn lock(&self, id: ObjId) -> Lock {
        self.locks[id.idx()]
    }

    /// Resolve an address to its Memory object.
    #[inline]
    pub fn memory_of(&self, addr: Addr) -> Option<ObjId> {
        // addr_map is sorted by range start; ranges are disjoint
        match self.addr_map.binary_search_by(|&(s, _, _)| s.cmp(&addr)) {
            Ok(i) => Some(self.addr_map[i].2),
            Err(0) => None,
            Err(i) => {
                let (s, e, m) = self.addr_map[i - 1];
                (addr >= s && addr < e).then_some(m)
            }
        }
    }

    /// Iterate `(id, object)` pairs.
    pub fn objects_iter(&self) -> impl Iterator<Item = (ObjId, &Object)> {
        self.objects
            .iter()
            .enumerate()
            .map(|(i, o)| (ObjId(i as u32), o))
    }

    // ---- finalize ----------------------------------------------------------

    /// Build derived tables and validate the diagram. Must be called after
    /// construction and before routing/evaluation.
    pub fn finalize(&mut self) -> Result<()> {
        let n = self.objects.len();
        self.fetch.context("diagram must declare a fetch front-end (add_fetch)")?;

        // writeBack pseudo-object
        let wb = self.push("writeBack", ObjectKind::WriteBack);
        self.writeback = Some(wb);

        // register homes
        let mut homes = vec![ObjId(u32::MAX); self.regs.len()];
        for (i, o) in self.objects.iter().enumerate() {
            if let ObjectKind::RegisterFile { regs, .. } = &o.kind {
                for r in regs {
                    if homes[r.0 as usize] != ObjId(u32::MAX) {
                        bail!("register {} homed in two register files", self.regs.name(r.0));
                    }
                    homes[r.0 as usize] = ObjId(i as u32);
                }
            }
        }
        for (r, h) in homes.iter().enumerate() {
            if *h == ObjId(u32::MAX) {
                bail!("register {} has no register file", self.regs.name(r as u32));
            }
        }
        self.reg_home = homes;

        // candidate FUs per op
        self.op_fus.clear();
        for (i, o) in self.objects.iter().enumerate() {
            if let ObjectKind::FunctionalUnit { to_process, .. } = &o.kind {
                for op in to_process {
                    self.op_fus.entry(*op).or_default().push(ObjId(i as u32));
                }
            }
        }

        // structural locks: FU inside an ES locks the ES; memory capacity =
        // max_concurrent_requests; writeBack exempt (capacity u32::MAX).
        let mut locks: Vec<Lock> = (0..self.objects.len())
            .map(|i| Lock { owner: ObjId(i as u32), capacity: 1 })
            .collect();
        for (es, fus) in self.contains.iter().enumerate().take(n) {
            for fu in fus {
                locks[fu.idx()].owner = ObjId(es as u32);
            }
        }
        for (i, o) in self.objects.iter().enumerate() {
            match &o.kind {
                ObjectKind::Memory { max_concurrent_requests, .. } => {
                    locks[i].capacity = *max_concurrent_requests;
                }
                // the issue buffer holds issue_buffer_size instructions: the
                // i-th instruction enters once the (i - size)-th left (§4.1
                // "fetch as long as the issue buffer is not full")
                ObjectKind::InstructionFetchStage { issue_buffer_size, .. } => {
                    locks[i].capacity = *issue_buffer_size;
                }
                ObjectKind::WriteBack => locks[i].capacity = u32::MAX,
                _ => {}
            }
        }
        self.locks = locks;

        // address map
        let mut ranges = Vec::new();
        for (i, o) in self.objects.iter().enumerate() {
            if let ObjectKind::Memory { address_ranges, .. } = &o.kind {
                for &(s, e) in address_ranges {
                    if e > s {
                        ranges.push((s, e, ObjId(i as u32)));
                    }
                }
            }
        }
        ranges.sort_by_key(|&(s, _, _)| s);
        for w in ranges.windows(2) {
            if w[0].1 > w[1].0 {
                bail!(
                    "overlapping address ranges: {} and {}",
                    self.objects[w[0].2.idx()].name,
                    self.objects[w[1].2.idx()].name
                );
            }
        }
        self.addr_map = ranges;

        // per-FU stage path: BFS from the IFS through forward edges to the
        // FU's containing ExecuteStage, collecting intermediate
        // PipelineStages (ES latency is not accumulated; paper §4.1).
        let ifs = self.fetch.unwrap().fetch_stage;
        let mut es_of_fu: HashMap<ObjId, ObjId> = HashMap::new();
        for (es, fus) in self.contains.iter().enumerate() {
            for fu in fus {
                es_of_fu.insert(*fu, ObjId(es as u32));
            }
        }
        let mut stage_path = vec![Vec::new(); self.objects.len()];
        for (&fu, &es) in &es_of_fu {
            let path = self.bfs_stages(ifs, es).with_context(|| {
                format!(
                    "no forward path from fetch stage to execute stage {}",
                    self.objects[es.idx()].name
                )
            })?;
            stage_path[fu.idx()] = path;
        }
        self.stage_path = stage_path;

        self.route_cache.lock().unwrap().clear();
        self.finalized = true;
        Ok(())
    }

    /// BFS over forward edges from `from` to `to`, returning intermediate
    /// PipelineStage objects (excluding endpoints, skipping ExecuteStages).
    fn bfs_stages(&self, from: ObjId, to: ObjId) -> Option<Vec<ObjId>> {
        if from == to {
            return Some(Vec::new());
        }
        let mut prev: HashMap<ObjId, ObjId> = HashMap::new();
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(from);
        while let Some(cur) = queue.pop_front() {
            for &nxt in &self.forward[cur.idx()] {
                if nxt != from && !prev.contains_key(&nxt) {
                    prev.insert(nxt, cur);
                    if nxt == to {
                        // reconstruct, keep only PipelineStages strictly
                        // between the endpoints
                        let mut path = Vec::new();
                        let mut n = to;
                        while let Some(&p) = prev.get(&n) {
                            if p != from {
                                if matches!(
                                    self.objects[p.idx()].kind,
                                    ObjectKind::PipelineStage { .. }
                                ) {
                                    path.push(p);
                                }
                            }
                            n = p;
                            if n == from {
                                break;
                            }
                        }
                        path.reverse();
                        return Some(path);
                    }
                    queue.push_back(nxt);
                }
            }
        }
        None
    }

    /// Number of functional units — the DSE reports' PE-count cost proxy
    /// (every compute/move/memory-access unit counts once).
    pub fn fu_count(&self) -> usize {
        self.objects
            .iter()
            .filter(|o| matches!(o.kind, ObjectKind::FunctionalUnit { .. }))
            .count()
    }

    /// Total words claimed by data memories — the DSE reports' memory cost
    /// proxy (sums every memory's address ranges, saturating).
    pub fn memory_words(&self) -> u64 {
        let mut total = 0u64;
        for o in &self.objects {
            if let ObjectKind::Memory { address_ranges, .. } = &o.kind {
                for &(start, end) in address_ranges {
                    total = total.saturating_add(end.saturating_sub(start));
                }
            }
        }
        total
    }

    /// Structural content digest of a finalized diagram: a hash over every
    /// primitive table that can influence routing or timing — object kinds
    /// (with latencies, port widths, capacities, address ranges), all
    /// association edges, and the fetch front-end. Object and register
    /// *names* are deliberately excluded: estimation only sees interned ids,
    /// so two structurally identical diagrams (e.g. a hand builder and its
    /// textual description) digest equally and can share cached kernel
    /// estimates (`crate::engine`). Derived tables (locks, address map,
    /// stage paths) are functions of the hashed primitives and need not be
    /// hashed themselves.
    pub fn content_digest(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        assert!(self.finalized, "content_digest requires a finalized diagram");
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.objects.len().hash(&mut h);
        for o in &self.objects {
            o.kind.hash(&mut h);
        }
        for assoc in [
            &self.forward,
            &self.contains,
            &self.fu_read_rf,
            &self.fu_write_rf,
            &self.fu_read_mem,
            &self.fu_write_mem,
        ] {
            for edges in assoc.iter() {
                edges.hash(&mut h);
            }
        }
        let f = self.fetch.as_ref().expect("finalized diagram has fetch");
        (f.instr_mem, f.port_width, f.read_latency, f.fetch_stage, f.ifs_latency)
            .hash(&mut h);
        f.issue_buffer_size.hash(&mut h);
        h.finish()
    }

    // ---- routing -----------------------------------------------------------

    /// Memory objects serving `addrs`, deduped in first-occurrence order.
    fn mems_for(&self, addrs: &[Addr]) -> Result<Vec<ObjId>> {
        let mut mems: Vec<ObjId> = Vec::new();
        for &a in addrs {
            let m = self
                .memory_of(a)
                .with_context(|| format!("address {a:#x} not claimed by any memory"))?;
            if !mems.contains(&m) {
                mems.push(m);
            }
        }
        Ok(mems)
    }

    fn fu_can_access(&self, fu: ObjId, instr: &Instruction, rmems: &[ObjId], wmems: &[ObjId]) -> bool {
        let readable = &self.fu_read_rf[fu.idx()];
        let writable = &self.fu_write_rf[fu.idx()];
        for r in &instr.read_regs {
            if !readable.contains(&self.reg_home[r.0 as usize]) {
                return false;
            }
        }
        for r in &instr.write_regs {
            if !writable.contains(&self.reg_home[r.0 as usize]) {
                return false;
            }
        }
        for m in rmems {
            if !self.fu_read_mem[fu.idx()].contains(m) {
                return false;
            }
        }
        for m in wmems {
            if !self.fu_write_mem[fu.idx()].contains(m) {
                return false;
            }
        }
        true
    }

    /// Route `instr` through the diagram: find the supporting FU and the
    /// object order `o⃗(i)`. Cached by (op, registers, memories) — the
    /// template signature that stays constant across loop iterations.
    pub fn route(&self, instr: &Instruction) -> Result<std::sync::Arc<Route>> {
        assert!(self.finalized, "diagram not finalized");
        let read_mems = self.mems_for(&instr.read_addrs)?;
        let write_mems = self.mems_for(&instr.write_addrs)?;
        let key = RouteKey {
            op: instr.op,
            read_regs: instr.read_regs.clone(),
            write_regs: instr.write_regs.clone(),
            read_mems: read_mems.clone(),
            write_mems: write_mems.clone(),
        };
        if let Some(r) = self.route_cache.lock().unwrap().get(&key) {
            return Ok(r.clone());
        }
        let cands = self
            .op_fus
            .get(&instr.op)
            .with_context(|| format!("no functional unit supports op {}", self.op_name(instr.op)))?;
        let fu = cands
            .iter()
            .copied()
            .find(|&fu| self.fu_can_access(fu, instr, &read_mems, &write_mems))
            .with_context(|| {
                format!(
                    "no functional unit supporting {} can access the instruction's registers/memories",
                    self.op_name(instr.op)
                )
            })?;
        let route = std::sync::Arc::new(Route {
            stages: self.stage_path[fu.idx()].clone(),
            fu,
            has_writeback: !read_mems.is_empty(),
            read_mems,
            write_mems,
        });
        self.route_cache.lock().unwrap().insert(key, route.clone());
        Ok(route)
    }

    /// Latency of a memory transaction on `mem` covering `n_addrs` words:
    /// `ceil(n_addrs / port_width)` transactions of `latency` each.
    #[inline]
    pub fn mem_latency(&self, mem: ObjId, n_addrs: usize, write: bool, instr: &Instruction) -> u64 {
        self.mem_latency_imms(mem, n_addrs, write, &instr.imms)
    }

    /// [`Self::mem_latency`] against a raw immediate slice (iteration-
    /// program hot path).
    #[inline]
    pub fn mem_latency_imms(&self, mem: ObjId, n_addrs: usize, write: bool, imms: &[i64]) -> u64 {
        if let ObjectKind::Memory { read_latency, write_latency, port_width, .. } =
            &self.objects[mem.idx()].kind
        {
            let per = (if write { write_latency } else { read_latency }).eval_imms(imms);
            let txns = (n_addrs as u64).div_ceil(*port_width as u64).max(1);
            per * txns
        } else {
            0
        }
    }

    /// Per-transaction read/write latency of memory `mem` evaluated against
    /// a raw immediate slice (0 for non-memories).
    #[inline]
    pub fn mem_txn_latency_imms(&self, mem: ObjId, write: bool, imms: &[i64]) -> u64 {
        if let ObjectKind::Memory { read_latency, write_latency, .. } =
            &self.objects[mem.idx()].kind
        {
            (if write { write_latency } else { read_latency }).eval_imms(imms)
        } else {
            0
        }
    }

    /// Residency latency of `obj` evaluated against a raw immediate slice:
    /// pipeline-stage / fetch-stage / functional-unit latencies; 0 for every
    /// other kind (matching the evaluator's per-node latency dispatch).
    #[inline]
    pub fn object_latency_imms(&self, obj: ObjId, imms: &[i64]) -> u64 {
        match &self.objects[obj.idx()].kind {
            ObjectKind::PipelineStage { latency }
            | ObjectKind::InstructionFetchStage { latency, .. }
            | ObjectKind::FunctionalUnit { latency, .. } => latency.eval_imms(imms),
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal diagram: fetch + one ES with one FU reading/writing one RF
    /// and accessing one memory.
    fn tiny() -> (Diagram, OpId, Vec<RegId>) {
        let mut d = Diagram::new("tiny");
        let (_imem, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
        let es = d.add_execute_stage("es0");
        let (rf, regs) = d.add_regfile("rf0", "r", 4);
        let mem = d.add_memory("dmem", 4, 4, 2, 1, 0, 1024);
        let alu = d.add_fu(es, "alu0", Latency::Fixed(1), &["add", "load"]);
        d.forward(ifs, es);
        d.fu_reads(alu, rf);
        d.fu_writes(alu, rf);
        d.mem_reads(alu, mem);
        d.mem_writes(alu, mem);
        let op = d.op("add");
        d.finalize().unwrap();
        (d, op, regs)
    }

    #[test]
    fn finalize_builds_tables() {
        let (d, _, _) = tiny();
        assert!(d.memory_of(0).is_some());
        assert!(d.memory_of(1023).is_some());
        assert_eq!(d.memory_of(1024), None);
        assert_eq!(d.fetch_config().port_width, 2);
    }

    #[test]
    fn route_compute_instruction() {
        let (d, op, regs) = tiny();
        let i = Instruction::new(op).reads(&[regs[0]]).writes(&[regs[1]]);
        let r = d.route(&i).unwrap();
        assert!(r.read_mems.is_empty() && r.write_mems.is_empty());
        assert!(!r.has_writeback);
        assert_eq!(d.object(r.fu).name, "alu0");
    }

    #[test]
    fn route_load_has_writeback() {
        let (mut d, _, regs) = tiny();
        let load = d.op("load");
        let i = Instruction::new(load).writes(&[regs[0]]).read_mem(&[16]);
        let r = d.route(&i).unwrap();
        assert_eq!(r.read_mems.len(), 1);
        assert!(r.has_writeback);
    }

    #[test]
    fn route_cache_hit_is_same_arc() {
        let (d, op, regs) = tiny();
        let i1 = Instruction::new(op).reads(&[regs[0]]).writes(&[regs[1]]);
        let i2 = i1.clone();
        let r1 = d.route(&i1).unwrap();
        let r2 = d.route(&i2).unwrap();
        assert!(std::sync::Arc::ptr_eq(&r1, &r2));
    }

    #[test]
    fn unknown_op_fails() {
        let (mut d, _, _) = tiny();
        let mul = d.op("mul");
        d.finalize().unwrap();
        assert!(d.route(&Instruction::new(mul)).is_err());
    }

    #[test]
    fn unclaimed_address_fails() {
        let (d, op, regs) = tiny();
        let i = Instruction::new(op).reads(&[regs[0]]).read_mem(&[99999]);
        assert!(d.route(&i).is_err());
    }

    #[test]
    fn inaccessible_register_fails() {
        let (mut d, op, _) = tiny();
        // a second RF nobody reads
        let (_rf2, regs2) = d.add_regfile("rf1", "s", 2);
        d.finalize().unwrap();
        let i = Instruction::new(op).reads(&[regs2[0]]);
        assert!(d.route(&i).is_err());
    }

    #[test]
    fn mem_latency_transactions() {
        let (d, op, _) = tiny();
        let mem = d.memory_of(0).unwrap();
        let i = Instruction::new(op);
        // port_width 2, read latency 4: 3 addrs -> 2 txns -> 8 cycles
        assert_eq!(d.mem_latency(mem, 3, false, &i), 8);
        assert_eq!(d.mem_latency(mem, 1, false, &i), 4);
        assert_eq!(d.mem_latency(mem, 0, true, &i), 4); // clamped min 1 txn
    }

    #[test]
    fn content_digest_is_structural() {
        let (d1, _, _) = tiny();
        let (d2, _, _) = tiny();
        // independently built but identical structures digest equally
        assert_eq!(d1.content_digest(), d2.content_digest());
        // any timing-relevant knob moves the digest
        let variant = |mem_ports: u32| {
            let mut d = Diagram::new("tiny");
            let (_imem, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
            let es = d.add_execute_stage("es0");
            let (rf, _regs) = d.add_regfile("rf0", "r", 4);
            let mem = d.add_memory("dmem", 4, 4, 2, mem_ports, 0, 1024);
            let alu = d.add_fu(es, "alu0", Latency::Fixed(1), &["add", "load"]);
            d.forward(ifs, es);
            d.fu_reads(alu, rf);
            d.fu_writes(alu, rf);
            d.mem_reads(alu, mem);
            d.mem_writes(alu, mem);
            d.finalize().unwrap();
            d.content_digest()
        };
        assert_eq!(variant(1), d1.content_digest());
        assert_ne!(variant(2), d1.content_digest());
    }

    #[test]
    fn sibling_fus_share_lock() {
        let mut d = Diagram::new("sib");
        let (_im, ifs) = d.add_fetch("imem", 1, 1, "ifs", 1, 2);
        let es = d.add_execute_stage("es");
        let (rf, _regs) = d.add_regfile("rf", "r", 2);
        let a = d.add_fu(es, "a", Latency::Fixed(1), &["x"]);
        let b = d.add_fu(es, "b", Latency::Fixed(1), &["y"]);
        d.fu_reads(a, rf);
        d.fu_reads(b, rf);
        d.forward(ifs, es);
        d.finalize().unwrap();
        assert_eq!(d.lock(a).owner, d.lock(b).owner);
        assert_eq!(d.lock(a).capacity, 1);
    }
}
