//! Latency semantics: fixed cycle counts or *expressions* evaluated at
//! estimation time against an instruction's immediates.
//!
//! The paper (§4.1 "latency") allows a latency to be "an integer value or a
//! string containing a function that is evaluated during the performance
//! estimation". This is how coarse models fold analytical sub-models into a
//! single FunctionalUnit: UltraTrail's `macArrayAndOPU` carries the CONV-EXT
//! analytical model parameterized by the instruction's immediates (paper
//! Fig. 5/6), and Gemmini's DRAM uses a linear burst model over the accessed
//! data volume and start address (paper §7.2).
//!
//! Expression grammar (integer arithmetic, i64):
//! ```text
//! expr  := term (('+'|'-') term)*
//! term  := unary (('*'|'/'|'%') unary)*
//! unary := '-' unary | atom
//! atom  := INT | VAR | FN '(' expr (',' expr)* ')' | '(' expr ')'
//! VAR   := imm0 | imm1 | ...           (instruction immediates)
//! FN    := cdiv | max | min            (ceil-div, maximum, minimum)
//! ```

use anyhow::{anyhow, bail, Result};

use crate::ids::Cycle;
use crate::isa::Instruction;

/// A module latency: constant cycles or an expression over immediates.
/// (`Hash` feeds [`crate::acadl::Diagram::content_digest`] — the engine's
/// architecture fingerprint.)
#[derive(Debug, Clone, PartialEq, Hash)]
pub enum Latency {
    /// Constant latency.
    Fixed(Cycle),
    /// Immediate-dependent latency expression.
    Expr(Expr),
}

impl Latency {
    /// Parse either an integer literal or an expression.
    pub fn parse(src: &str) -> Result<Self> {
        let src = src.trim();
        if let Ok(v) = src.parse::<u64>() {
            return Ok(Latency::Fixed(v));
        }
        Ok(Latency::Expr(Expr::parse(src)?))
    }

    /// Evaluate against `instr`'s immediates; negative results clamp to 0.
    #[inline]
    pub fn eval(&self, instr: &Instruction) -> Cycle {
        self.eval_imms(&instr.imms)
    }

    /// Evaluate against a raw immediate slice (the iteration-program hot
    /// path, which carries operand slices instead of owned instructions);
    /// negative results clamp to 0.
    #[inline]
    pub fn eval_imms(&self, imms: &[i64]) -> Cycle {
        match self {
            Latency::Fixed(c) => *c,
            Latency::Expr(e) => e.eval(imms).max(0) as Cycle,
        }
    }

    /// True if the latency does not depend on the instruction.
    pub fn is_fixed(&self) -> bool {
        matches!(self, Latency::Fixed(_))
    }
}

impl From<u64> for Latency {
    fn from(v: u64) -> Self {
        Latency::Fixed(v)
    }
}

/// Parsed latency expression AST.
#[derive(Debug, Clone, PartialEq, Hash)]
pub enum Expr {
    /// Integer constant.
    Const(i64),
    /// `immN` — index into [`Instruction::imms`]; missing entries read 0.
    Imm(usize),
    /// Negation.
    Neg(Box<Expr>),
    /// Addition.
    Add(Box<Expr>, Box<Expr>),
    /// Subtraction.
    Sub(Box<Expr>, Box<Expr>),
    /// Multiplication.
    Mul(Box<Expr>, Box<Expr>),
    /// Floor division; division by zero yields 0.
    Div(Box<Expr>, Box<Expr>),
    /// Remainder; a zero divisor yields 0.
    Rem(Box<Expr>, Box<Expr>),
    /// Ceil division; division by zero yields 0.
    Cdiv(Box<Expr>, Box<Expr>),
    /// Maximum.
    Max(Box<Expr>, Box<Expr>),
    /// Minimum.
    Min(Box<Expr>, Box<Expr>),
}

impl Expr {
    /// Parse a latency expression string.
    pub fn parse(src: &str) -> Result<Self> {
        let mut p = Parser { toks: lex(src)?, pos: 0 };
        let e = p.expr()?;
        if p.pos != p.toks.len() {
            bail!("trailing tokens in latency expression {src:?}");
        }
        Ok(e)
    }

    /// Evaluate against an instruction's immediates.
    pub fn eval(&self, imms: &[i64]) -> i64 {
        match self {
            Expr::Const(v) => *v,
            Expr::Imm(i) => imms.get(*i).copied().unwrap_or(0),
            Expr::Neg(a) => -a.eval(imms),
            Expr::Add(a, b) => a.eval(imms).wrapping_add(b.eval(imms)),
            Expr::Sub(a, b) => a.eval(imms).wrapping_sub(b.eval(imms)),
            Expr::Mul(a, b) => a.eval(imms).wrapping_mul(b.eval(imms)),
            Expr::Div(a, b) => {
                let d = b.eval(imms);
                if d == 0 { 0 } else { a.eval(imms).div_euclid(d) }
            }
            Expr::Rem(a, b) => {
                let d = b.eval(imms);
                if d == 0 { 0 } else { a.eval(imms).rem_euclid(d) }
            }
            Expr::Cdiv(a, b) => {
                let d = b.eval(imms);
                if d == 0 { 0 } else { (a.eval(imms) + d - 1).div_euclid(d) }
            }
            Expr::Max(a, b) => a.eval(imms).max(b.eval(imms)),
            Expr::Min(a, b) => a.eval(imms).min(b.eval(imms)),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Int(i64),
    Ident(String),
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    LParen,
    RParen,
    Comma,
}

fn lex(src: &str) -> Result<Vec<Tok>> {
    let mut toks = Vec::new();
    let bytes = src.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        match c {
            ' ' | '\t' | '\n' => i += 1,
            '+' => { toks.push(Tok::Plus); i += 1 }
            '-' => { toks.push(Tok::Minus); i += 1 }
            '*' => { toks.push(Tok::Star); i += 1 }
            '/' => { toks.push(Tok::Slash); i += 1 }
            '%' => { toks.push(Tok::Percent); i += 1 }
            '(' => { toks.push(Tok::LParen); i += 1 }
            ')' => { toks.push(Tok::RParen); i += 1 }
            ',' => { toks.push(Tok::Comma); i += 1 }
            '0'..='9' => {
                let s = i;
                while i < bytes.len() && bytes[i].is_ascii_digit() {
                    i += 1;
                }
                toks.push(Tok::Int(src[s..i].parse()?));
            }
            'a'..='z' | 'A'..='Z' | '_' => {
                let s = i;
                while i < bytes.len()
                    && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_')
                {
                    i += 1;
                }
                toks.push(Tok::Ident(src[s..i].to_string()));
            }
            _ => bail!("unexpected character {c:?} in latency expression"),
        }
    }
    Ok(toks)
}

struct Parser {
    toks: Vec<Tok>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, t: Tok) -> Result<()> {
        match self.next() {
            Some(got) if got == t => Ok(()),
            got => bail!("expected {t:?}, got {got:?}"),
        }
    }

    fn expr(&mut self) -> Result<Expr> {
        let mut lhs = self.term()?;
        loop {
            match self.peek() {
                Some(Tok::Plus) => {
                    self.pos += 1;
                    lhs = Expr::Add(Box::new(lhs), Box::new(self.term()?));
                }
                Some(Tok::Minus) => {
                    self.pos += 1;
                    lhs = Expr::Sub(Box::new(lhs), Box::new(self.term()?));
                }
                _ => return Ok(lhs),
            }
        }
    }

    fn term(&mut self) -> Result<Expr> {
        let mut lhs = self.unary()?;
        loop {
            match self.peek() {
                Some(Tok::Star) => {
                    self.pos += 1;
                    lhs = Expr::Mul(Box::new(lhs), Box::new(self.unary()?));
                }
                Some(Tok::Slash) => {
                    self.pos += 1;
                    lhs = Expr::Div(Box::new(lhs), Box::new(self.unary()?));
                }
                Some(Tok::Percent) => {
                    self.pos += 1;
                    lhs = Expr::Rem(Box::new(lhs), Box::new(self.unary()?));
                }
                _ => return Ok(lhs),
            }
        }
    }

    fn unary(&mut self) -> Result<Expr> {
        if matches!(self.peek(), Some(Tok::Minus)) {
            self.pos += 1;
            return Ok(Expr::Neg(Box::new(self.unary()?)));
        }
        self.atom()
    }

    fn atom(&mut self) -> Result<Expr> {
        match self.next() {
            Some(Tok::Int(v)) => Ok(Expr::Const(v)),
            Some(Tok::LParen) => {
                let e = self.expr()?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            Some(Tok::Ident(name)) => {
                if let Some(idx) = name.strip_prefix("imm") {
                    if let Ok(i) = idx.parse::<usize>() {
                        return Ok(Expr::Imm(i));
                    }
                }
                // two-argument builtin functions
                self.expect(Tok::LParen)?;
                let a = self.expr()?;
                self.expect(Tok::Comma)?;
                let b = self.expr()?;
                self.expect(Tok::RParen)?;
                let (a, b) = (Box::new(a), Box::new(b));
                match name.as_str() {
                    "cdiv" => Ok(Expr::Cdiv(a, b)),
                    "max" => Ok(Expr::Max(a, b)),
                    "min" => Ok(Expr::Min(a, b)),
                    other => Err(anyhow!("unknown function {other:?} in latency expression")),
                }
            }
            got => bail!("unexpected token {got:?} in latency expression"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::OpId;

    fn instr(imms: &[i64]) -> Instruction {
        Instruction::new(OpId(0)).imms(imms)
    }

    #[test]
    fn fixed_roundtrip() {
        let l = Latency::parse("42").unwrap();
        assert_eq!(l, Latency::Fixed(42));
        assert_eq!(l.eval(&instr(&[])), 42);
        assert!(l.is_fixed());
    }

    #[test]
    fn arithmetic_precedence() {
        let l = Latency::parse("1 + 2 * 3").unwrap();
        assert_eq!(l.eval(&instr(&[])), 7);
        let l = Latency::parse("(1 + 2) * 3").unwrap();
        assert_eq!(l.eval(&instr(&[])), 9);
    }

    #[test]
    fn immediates_and_functions() {
        // ceil(C/8) * ceil(K/8) * F * Cw  — a CONV-EXT-like model
        let l = Latency::parse("cdiv(imm0, 8) * cdiv(imm1, 8) * imm2 * imm3").unwrap();
        let i = instr(&[16, 12, 3, 25]);
        assert_eq!(l.eval(&i), 2 * 2 * 3 * 25);
    }

    #[test]
    fn max_min_neg() {
        let l = Latency::parse("max(imm0, imm1) + min(imm0, imm1) - imm0").unwrap();
        assert_eq!(l.eval(&instr(&[3, 9])), 9);
        // negative clamps to zero as a latency
        let l = Latency::parse("0 - 5").unwrap();
        assert_eq!(l.eval(&instr(&[])), 0);
    }

    #[test]
    fn div_by_zero_is_zero() {
        let l = Latency::parse("imm0 / imm1 + cdiv(imm0, imm1) + imm0 % imm1").unwrap();
        assert_eq!(l.eval(&instr(&[5, 0])), 0);
    }

    #[test]
    fn missing_imm_reads_zero() {
        let l = Latency::parse("imm7 + 3").unwrap();
        assert_eq!(l.eval(&instr(&[1])), 3);
    }

    #[test]
    fn parse_errors() {
        assert!(Latency::parse("foo(1,2)").is_err());
        assert!(Latency::parse("1 +").is_err());
        assert!(Latency::parse("(1").is_err());
        assert!(Latency::parse("1 2").is_err());
        assert!(Latency::parse("$").is_err());
    }
}
