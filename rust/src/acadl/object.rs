//! ACADL objects: the basic building blocks of computer architectures.
//!
//! Mirrors the paper's class diagram (Fig. 2) with the classes that carry
//! timing semantics. Pure-container classes (`Data`) and virtual bases
//! (`ACADLObject`, `DataStorage`, `MemoryInterface`) have no runtime
//! representation of their own; `MemoryAccessUnit` /
//! `InstructionMemoryAccessUnit` are functional units distinguished by their
//! memory associations, exactly as in the object diagrams of §4.3.

use crate::acadl::latency::Latency;
use crate::ids::{Addr, Cycle, ObjId, OpId, RegId};

/// Kind + attributes of one ACADL object.
/// (`Hash` feeds [`crate::acadl::Diagram::content_digest`] — the engine's
/// architecture fingerprint.)
#[derive(Debug, Clone, Hash)]
pub enum ObjectKind {
    /// Forwards instructions; an instruction resides `latency` cycles inside
    /// before being forwarded (paper: PipelineStage).
    PipelineStage {
        /// Residency before forwarding.
        latency: Latency,
    },

    /// Receives instructions and dispatches them to a contained
    /// FunctionalUnit; its own latency is *not* accumulated when a contained
    /// FU accepts the instruction (paper: ExecuteStage). Acts as the
    /// structural lock domain for its sibling FUs.
    ExecuteStage,

    /// Fetches from the instruction memory into an issue buffer and can
    /// issue multiple instructions per cycle up to `issue_buffer_size`
    /// (paper: InstructionFetchStage).
    InstructionFetchStage {
        /// Fetch-stage residency.
        latency: Latency,
        /// Issue-buffer depth.
        issue_buffer_size: u32,
    },

    /// Executes instructions whose operation is in `to_process`, taking
    /// `latency` cycles after data dependencies resolve (paper:
    /// FunctionalUnit; also MemoryAccessUnit when it has memory
    /// associations).
    FunctionalUnit {
        /// Execution latency (may ride on instruction immediates).
        latency: Latency,
        /// Operations this unit processes.
        to_process: Vec<OpId>,
    },

    /// Maps unique register names to values; access latency is implicit in
    /// the FUs that read/write it (paper: RegisterFile).
    RegisterFile {
        /// Register width in bits.
        data_width: u32,
        /// Registers this file owns.
        regs: Vec<RegId>,
    },

    /// Data storage with per-transaction latencies. `port_width` is the
    /// number of words per transaction; `max_concurrent_requests` bounds
    /// simultaneous transactions (paper: Memory + MemoryInterface).
    Memory {
        /// Read-transaction latency.
        read_latency: Latency,
        /// Write-transaction latency.
        write_latency: Latency,
        /// Word width in bits.
        data_width: u32,
        /// Words per transaction.
        port_width: u32,
        /// Simultaneous transactions.
        max_concurrent_requests: u32,
        /// Claimed half-open `[start, end)` address ranges.
        address_ranges: Vec<(Addr, Addr)>,
    },

    /// The pseudo-object anchoring load write-backs (§6.1): zero latency and
    /// exempt from structural dependencies.
    WriteBack,
}

/// One instantiated ACADL object.
#[derive(Debug, Clone)]
pub struct Object {
    /// Object name.
    pub name: String,
    /// Object kind and kind-specific configuration.
    pub kind: ObjectKind,
}

impl Object {
    /// Static latency if the object's latency is instruction-independent.
    pub fn fixed_latency(&self) -> Option<Cycle> {
        match &self.kind {
            ObjectKind::PipelineStage { latency }
            | ObjectKind::InstructionFetchStage { latency, .. }
            | ObjectKind::FunctionalUnit { latency, .. } => match latency {
                Latency::Fixed(c) => Some(*c),
                Latency::Expr(_) => None,
            },
            ObjectKind::WriteBack => Some(0),
            _ => None,
        }
    }

    /// True for memory objects.
    pub fn is_memory(&self) -> bool {
        matches!(self.kind, ObjectKind::Memory { .. })
    }

    /// True for functional units.
    pub fn is_functional_unit(&self) -> bool {
        matches!(self.kind, ObjectKind::FunctionalUnit { .. })
    }
}

/// Structural-capacity descriptor: which object arbitrates occupancy for a
/// node, and how many concurrent occupants it allows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lock {
    /// Lock owner (an ExecuteStage for sibling FUs, the object itself
    /// otherwise).
    pub owner: ObjId,
    /// Concurrent occupancy (1 except memories with
    /// `max_concurrent_requests > 1`).
    pub capacity: u32,
}
