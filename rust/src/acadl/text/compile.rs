//! Expansion and compilation: template AST → [`Flat`] instance list →
//! [`crate::acadl::Diagram`] → [`CompiledModel`] (diagram bound to a mapper
//! family so described architectures drop into the existing estimation
//! stack).

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use anyhow::{bail, Context as _};

use crate::acadl::latency::Latency;
use crate::acadl::Diagram;
use crate::accel::{
    Gemmini, GemminiConfig, Plasticine, PlasticineConfig, Systolic, SystolicConfig, UltraTrail,
    UltraTrailConfig,
};
use crate::ids::ObjId;
use crate::mapping::{
    gemm_tile::GemmTileMapper, plasticine_map::PlasticineMapper, scalar::ScalarMapper,
    tensor_op::TensorOpMapper, Mapper,
};
use crate::Result;

use super::ast::{
    collect_vars, Decl, DeclBody, Description, PExpr, Segment, Span, Spanned, Sweep, SweepItem,
    Template,
};
use super::validate::validate;
use super::{parse, Diagnostic};

/// Replication safety cap: instances per declaration.
const MAX_INSTANCES_PER_DECL: usize = 1 << 20;

/// Default combinatorial cap of a `[sweep]` space (candidates). Override
/// per description with `cap = N` in `[sweep]`, or per run with the CLI's
/// `--sweep-cap`.
pub const DEFAULT_SWEEP_CAP: usize = 4096;

/// A fully expanded description: concrete objects and edges, no templates.
#[derive(Debug, Clone, Default)]
pub struct Flat {
    /// Expanded architecture name.
    pub name: String,
    /// Parameter values.
    pub params: BTreeMap<String, i64>,
    /// Declared ops (`None` = no `[isa]` section).
    pub isa: Option<Vec<Spanned<String>>>,
    /// Mapper family.
    pub mapper: Option<Spanned<String>>,
    /// Fetch front-end.
    pub fetch: Option<FlatFetch>,
    /// Expanded objects in declaration order.
    pub objects: Vec<FlatObject>,
    /// Expanded association edges.
    pub edges: Vec<FlatEdge>,
    /// Evaluated `[sweep]` design space (ignored by diagram compilation;
    /// consumed by [`crate::dse`]).
    pub sweep: Option<FlatSweep>,
}

/// One evaluated sweep dimension: the swept parameter and its concrete
/// value list in declaration order.
#[derive(Debug, Clone)]
pub struct FlatSweepDim {
    /// The swept `[params]` entry.
    pub name: String,
    /// Concrete values (items evaluated against the base `[params]`).
    pub values: Vec<i64>,
    /// Span of the dimension's value string.
    pub span: Span,
}

/// The evaluated `[sweep]` section.
#[derive(Debug, Clone)]
pub struct FlatSweep {
    /// Dimensions in declaration order (last varies fastest).
    pub dims: Vec<FlatSweepDim>,
    /// Candidate guard (evaluated per combination by the enumerator).
    pub when: Option<Spanned<PExpr>>,
    /// Combinatorial cap ([`DEFAULT_SWEEP_CAP`] unless overridden).
    pub cap: usize,
    /// Span of the `[sweep]` header.
    pub span: Span,
}

impl FlatSweep {
    /// Upper bound on the candidate count: the product of the dimension
    /// sizes (guards can only shrink the space).
    pub fn len_bound(&self) -> usize {
        self.dims.iter().fold(1usize, |acc, d| acc.saturating_mul(d.values.len()))
    }
}

#[derive(Debug, Clone)]
/// Expanded `[fetch]` front-end.
pub struct FlatFetch {
    /// Instruction-memory name.
    pub imem: String,
    /// Instruction-memory read latency.
    pub read_latency: i64,
    /// Instructions per fetch transaction.
    pub port_width: i64,
    /// Fetch-stage name.
    pub ifs: String,
    /// Fetch-stage latency.
    pub ifs_latency: i64,
    /// Issue-buffer depth.
    pub issue_buffer: i64,
    /// Span of the `[fetch]` header.
    pub span: Span,
}

#[derive(Debug, Clone)]
/// One expanded object.
pub struct FlatObject {
    /// Expanded (concrete) name.
    pub name: Spanned<String>,
    /// Kind and attributes.
    pub kind: FlatObjKind,
}

#[derive(Debug, Clone)]
/// Kind-specific attributes of an expanded object.
pub enum FlatObjKind {
    /// A pipeline stage.
    Stage {
        /// Residency latency.
        latency: Latency,
    },
    /// An execute stage.
    ExecuteStage,
    /// A functional unit.
    FunctionalUnit {
        /// Containing execute stage, when given via `in = "..."`.
        container: Option<Spanned<String>>,
        /// Execution latency.
        latency: Latency,
        /// Operations the unit processes.
        ops: Vec<Spanned<String>>,
    },
    /// A register file.
    RegisterFile {
        /// Register-name prefix.
        prefix: String,
        /// Register count.
        count: i64,
    },
    /// A data memory.
    Memory {
        /// Read-transaction latency.
        read_latency: Latency,
        /// Write-transaction latency.
        write_latency: Latency,
        /// Words per transaction.
        port_width: i64,
        /// Simultaneous transactions.
        max_concurrent: i64,
        /// Claimed address-range base.
        base: i64,
        /// Claimed address-range size in words.
        words: i64,
    },
}

impl FlatObjKind {
    /// Human-readable kind name for diagnostics.
    pub fn kind_name(&self) -> &'static str {
        match self {
            FlatObjKind::Stage { .. } => "pipeline stage",
            FlatObjKind::ExecuteStage => "execute stage",
            FlatObjKind::FunctionalUnit { .. } => "functional unit",
            FlatObjKind::RegisterFile { .. } => "register file",
            FlatObjKind::Memory { .. } => "memory",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Which association an expanded edge declares.
pub enum EdgeKind {
    /// Pipeline routing.
    Forward,
    /// Containment.
    Contains,
    /// FU reads a register file.
    Reads,
    /// FU writes a register file.
    Writes,
    /// FU reads a memory.
    MemRead,
    /// FU writes a memory.
    MemWrite,
}

#[derive(Debug, Clone)]
/// One expanded association edge.
pub struct FlatEdge {
    /// Association kind.
    pub kind: EdgeKind,
    /// Source / container / functional-unit endpoint.
    pub a: Spanned<String>,
    /// Target / contained / storage endpoint.
    pub b: Spanned<String>,
}

// ---- expansion -------------------------------------------------------------

/// Expand `foreach`/`when`/`${}` templates into a [`Flat`] description.
/// Collects diagnostics instead of failing fast; on errors the returned
/// `Flat` is best-effort (do not compile it).
pub fn expand(desc: &Description) -> (Flat, Vec<Diagnostic>) {
    let mut flat = Flat::default();
    let mut diags = Vec::new();

    for p in &desc.params {
        if flat.params.insert(p.name.node.clone(), p.value.node).is_some() {
            diags.push(Diagnostic::error(
                p.name.span,
                format!("duplicate parameter `{}`", p.name.node),
            ));
        }
    }
    flat.isa = desc.isa.clone();
    flat.mapper = desc.mapper.clone();

    let params = flat.params.clone();
    let env0 = Env { params: &params, vars: Vec::new(), idx: 0 };

    match &desc.name {
        Some(t) => match render(t, &env0) {
            Ok(n) => flat.name = n,
            Err(d) => diags.push(d),
        },
        None => {
            diags.push(Diagnostic::error(
                Span::default(),
                "missing [arch] section with `name = \"...\"`",
            ));
            flat.name = "described".into();
        }
    }

    if let Some(f) = &desc.fetch {
        let fetch = (|| -> std::result::Result<FlatFetch, Diagnostic> {
            Ok(FlatFetch {
                imem: render(&f.imem, &env0)?,
                read_latency: eval(&f.imem_read_latency, &env0)?,
                port_width: eval(&f.imem_port_width, &env0)?,
                ifs: render(&f.ifs, &env0)?,
                ifs_latency: eval(&f.ifs_latency, &env0)?,
                issue_buffer: eval(&f.issue_buffer, &env0)?,
                span: f.span,
            })
        })();
        match fetch {
            Ok(fc) => flat.fetch = Some(fc),
            Err(d) => diags.push(d),
        }
    }

    for decl in &desc.decls {
        expand_decl(decl, &params, &mut flat, &mut diags);
    }
    if let Some(sweep) = &desc.sweep {
        flat.sweep = expand_sweep(sweep, desc, &params, &mut diags);
    }
    (flat, diags)
}

/// The mapper families and the `[params]` entries their binding reads:
/// `(family, required, optional)`. Single source of truth shared by the
/// validator's family checks, [`bind`]'s lookups, and the sweep
/// "unreferenced parameter" suppression — extend this table (not call
/// sites) when a family gains a knob.
pub(crate) const MAPPER_FAMILIES: &[(&str, &[&str], &[&str])] = &[
    (
        "scalar",
        &["rows", "cols"],
        &["port_width", "mem_read_latency", "mem_write_latency", "mem_concurrency"],
    ),
    ("tensor_op", &["array_dim"], &[]),
    (
        "gemm_tile",
        &["dim"],
        &["dram_base_latency", "dram_words_per_beat", "dram_row_words"],
    ),
    (
        "plasticine",
        &["rows", "cols", "tile"],
        &["simd_lanes", "pipe_depth", "switch_width"],
    ),
];

/// The `(required, optional)` parameter names a mapper family binds, or
/// `None` for an unknown family.
pub(crate) fn family_params(
    family: &str,
) -> Option<(&'static [&'static str], &'static [&'static str])> {
    MAPPER_FAMILIES.iter().find(|(f, _, _)| *f == family).map(|&(_, r, o)| (r, o))
}

/// Collect the variables of every `${}` hole in a template.
fn template_vars(t: &Template, out: &mut Vec<String>) {
    for seg in &t.segments {
        if let Segment::Expr(e) = seg {
            collect_vars(e, out);
        }
    }
}

/// Every variable name referenced by the description's templates and
/// expressions (name, fetch, declarations — not the sweep itself).
fn description_vars(desc: &Description) -> std::collections::HashSet<String> {
    let mut vars = Vec::new();
    if let Some(n) = &desc.name {
        template_vars(n, &mut vars);
    }
    if let Some(f) = &desc.fetch {
        template_vars(&f.imem, &mut vars);
        template_vars(&f.ifs, &mut vars);
        for e in [&f.imem_read_latency, &f.imem_port_width, &f.ifs_latency, &f.issue_buffer] {
            collect_vars(&e.node, &mut vars);
        }
    }
    for d in &desc.decls {
        match &d.body {
            DeclBody::Stage { name, latency } => {
                template_vars(name, &mut vars);
                template_vars(latency, &mut vars);
            }
            DeclBody::ExecuteStage { name } => template_vars(name, &mut vars),
            DeclBody::FunctionalUnit { name, container, latency, .. } => {
                template_vars(name, &mut vars);
                if let Some(c) = container {
                    template_vars(c, &mut vars);
                }
                template_vars(latency, &mut vars);
            }
            DeclBody::RegisterFile { name, prefix, count } => {
                template_vars(name, &mut vars);
                template_vars(prefix, &mut vars);
                collect_vars(&count.node, &mut vars);
            }
            DeclBody::Memory {
                name,
                read_latency,
                write_latency,
                port_width,
                max_concurrent,
                base,
                words,
            } => {
                template_vars(name, &mut vars);
                template_vars(read_latency, &mut vars);
                template_vars(write_latency, &mut vars);
                for e in [port_width, max_concurrent, base, words] {
                    collect_vars(&e.node, &mut vars);
                }
            }
            DeclBody::Forward { from: a, to: b }
            | DeclBody::Contains { parent: a, child: b }
            | DeclBody::Reads { fu: a, rf: b }
            | DeclBody::Writes { fu: a, rf: b }
            | DeclBody::MemRead { fu: a, mem: b }
            | DeclBody::MemWrite { fu: a, mem: b } => {
                template_vars(a, &mut vars);
                template_vars(b, &mut vars);
            }
        }
        for r in &d.foreach {
            collect_vars(&r.lo.node, &mut vars);
            collect_vars(&r.hi.node, &mut vars);
        }
        if let Some(w) = &d.when {
            collect_vars(&w.node, &mut vars);
        }
    }
    vars.into_iter().collect()
}

/// Evaluate a `[sweep]` section against the base `[params]`, reporting
/// every sweep diagnostic (unknown parameters, empty dimensions and
/// ranges, bad steps and caps, combinatorial blow-ups) with spans. Returns
/// `None` when the space is unusable.
fn expand_sweep(
    sweep: &Sweep,
    desc: &Description,
    params: &BTreeMap<String, i64>,
    diags: &mut Vec<Diagnostic>,
) -> Option<FlatSweep> {
    let before = diags.len();
    if sweep.dims.is_empty() {
        diags.push(Diagnostic::error(
            sweep.span,
            "[sweep] declares no dimensions (every key except `when`/`cap` sweeps a parameter)",
        ));
        return None;
    }
    // the cap is needed *before* dimension evaluation: it bounds how many
    // values a single range may materialize, so a typo like `0..4000000000`
    // is a diagnostic, not a 32 GB allocation
    let cap = match &sweep.cap {
        None => DEFAULT_SWEEP_CAP,
        Some(c) if c.node >= 1 => c.node as usize,
        Some(c) => {
            diags.push(Diagnostic::error(c.span, "sweep cap must be >= 1"));
            DEFAULT_SWEEP_CAP
        }
    };
    let referenced = description_vars(desc);
    let lookup = |n: &str| params.get(n).copied();
    let mut dims = Vec::with_capacity(sweep.dims.len());
    for dim in &sweep.dims {
        if !params.contains_key(&dim.name.node) {
            diags.push(Diagnostic::error(
                dim.name.span,
                format!(
                    "sweep dimension `{}` is not declared in [params]",
                    dim.name.node
                ),
            ));
            continue;
        }
        let mapper_bound = desc
            .mapper
            .as_ref()
            .and_then(|m| family_params(&m.node))
            .is_some_and(|(req, opt)| {
                let name = dim.name.node.as_str();
                req.contains(&name) || opt.contains(&name)
            });
        if !referenced.contains(&dim.name.node) && !mapper_bound {
            diags.push(Diagnostic::warning(
                dim.name.span,
                format!(
                    "sweep dimension `{}` is not referenced by any template or read by \
                     the mapper binding; its candidates share architecture structure",
                    dim.name.node
                ),
            ));
        }
        let mut values = Vec::new();
        let mut overflowed = false;
        for item in &dim.items {
            match eval_sweep_item(item, &lookup, cap) {
                Ok(mut vs) => {
                    if vs.is_empty() {
                        diags.push(Diagnostic::warning(
                            dim.span,
                            format!(
                                "sweep range `{}` of `{}` is empty",
                                item.source(),
                                dim.name.node
                            ),
                        ));
                    }
                    values.append(&mut vs);
                }
                Err(msg) => diags.push(Diagnostic::error(dim.span, msg)),
            }
            if values.len() > cap {
                diags.push(Diagnostic::error(
                    dim.span,
                    format!(
                        "sweep dimension `{}` has more than {cap} values, exceeding the \
                         cap (raise it with `cap = N` in [sweep] or --sweep-cap)",
                        dim.name.node
                    ),
                ));
                overflowed = true;
                break;
            }
        }
        if overflowed {
            continue;
        }
        let mut seen = std::collections::HashSet::new();
        for v in &values {
            if !seen.insert(*v) {
                diags.push(Diagnostic::warning(
                    dim.span,
                    format!("sweep dimension `{}` repeats value {v}", dim.name.node),
                ));
            }
        }
        if values.is_empty() {
            diags.push(Diagnostic::error(
                dim.span,
                format!("sweep dimension `{}` is empty", dim.name.node),
            ));
            continue;
        }
        dims.push(FlatSweepDim { name: dim.name.node.clone(), values, span: dim.span });
    }
    if let Some(w) = &sweep.when {
        let mut vars = Vec::new();
        collect_vars(&w.node, &mut vars);
        for v in vars {
            let swept = sweep.dims.iter().any(|d| d.name.node == v);
            if !swept && !params.contains_key(&v) {
                diags.push(Diagnostic::error(
                    w.span,
                    format!("unknown parameter `{v}` in sweep guard"),
                ));
            }
        }
    }
    let flat = FlatSweep { dims, when: sweep.when.clone(), cap, span: sweep.span };
    if flat.len_bound() > cap {
        diags.push(Diagnostic::error(
            sweep.span,
            format!(
                "sweep space spans {} candidates, exceeding the cap of {cap} (raise it \
                 with `cap = N` in [sweep] or the CLI's --sweep-cap)",
                flat.len_bound()
            ),
        ));
    }
    if diags[before..].iter().any(Diagnostic::is_error) {
        return None;
    }
    Some(flat)
}

/// Concrete values of one sweep item under the base parameters. Ranges are
/// size-checked against `cap` *before* materializing — a runaway range must
/// produce a diagnostic, never a giant allocation.
fn eval_sweep_item(
    item: &SweepItem,
    lookup: &dyn Fn(&str) -> Option<i64>,
    cap: usize,
) -> std::result::Result<Vec<i64>, String> {
    match item {
        SweepItem::Scalar(e) => Ok(vec![e.eval(lookup)?]),
        SweepItem::Range { lo, hi, step } => {
            let lo = lo.eval(lookup)?;
            let hi = hi.eval(lookup)?;
            let step = match step {
                Some(s) => s.eval(lookup)?,
                None => 1,
            };
            if step < 1 {
                return Err(format!("sweep range step must be >= 1 (got {step})"));
            }
            let count = if hi <= lo {
                0
            } else {
                ((hi as i128 - lo as i128 - 1) / step as i128 + 1) as u128
            };
            if count > cap as u128 {
                return Err(format!(
                    "sweep range {}..{} spans {count} values, exceeding the cap of {cap} \
                     (raise it with `cap = N` in [sweep] or --sweep-cap)",
                    lo, hi
                ));
            }
            let mut vs = Vec::with_capacity(count as usize);
            let mut v = lo;
            while v < hi {
                vs.push(v);
                v = v.saturating_add(step);
            }
            Ok(vs)
        }
    }
}

/// Variable environment: loop variables shadow `idx`, which shadows params.
struct Env<'a> {
    params: &'a BTreeMap<String, i64>,
    vars: Vec<(String, i64)>,
    idx: i64,
}

impl Env<'_> {
    fn lookup(&self, name: &str) -> Option<i64> {
        if let Some(&(_, v)) = self.vars.iter().rev().find(|(n, _)| n == name) {
            return Some(v);
        }
        if name == "idx" {
            return Some(self.idx);
        }
        self.params.get(name).copied()
    }
}

fn render(t: &Template, env: &Env<'_>) -> std::result::Result<String, Diagnostic> {
    t.render(&|n| env.lookup(n)).map_err(|e| Diagnostic::error(t.span, e))
}

fn eval(
    e: &Spanned<super::ast::PExpr>,
    env: &Env<'_>,
) -> std::result::Result<i64, Diagnostic> {
    e.node.eval(&|n| env.lookup(n)).map_err(|msg| Diagnostic::error(e.span, msg))
}

fn expand_decl(
    decl: &Decl,
    params: &BTreeMap<String, i64>,
    flat: &mut Flat,
    diags: &mut Vec<Diagnostic>,
) {
    let mut env = Env { params, vars: Vec::new(), idx: 0 };
    let mut emitted = 0usize;
    let mut visited = 0usize;
    expand_ranges(decl, 0, &mut env, &mut emitted, &mut visited, flat, diags);
}

fn expand_ranges(
    decl: &Decl,
    depth: usize,
    env: &mut Env<'_>,
    emitted: &mut usize,
    visited: &mut usize,
    flat: &mut Flat,
    diags: &mut Vec<Diagnostic>,
) {
    if depth == decl.foreach.len() {
        // the cap bounds *loop iterations*, not just guard-passing
        // instances — a huge range with a narrow `when` must still
        // terminate. Report once; the sentinel stops the range loops.
        *visited += 1;
        if *visited > MAX_INSTANCES_PER_DECL {
            if *visited == MAX_INSTANCES_PER_DECL + 1 {
                diags.push(Diagnostic::error(
                    decl.span,
                    format!(
                        "declaration iterates more than {MAX_INSTANCES_PER_DECL} times"
                    ),
                ));
            }
            return;
        }
        if let Some(w) = &decl.when {
            match eval(w, env) {
                Ok(0) => return,
                Ok(_) => {}
                Err(d) => {
                    // a guard that errors once errors for every iteration;
                    // report it once and stop expanding this declaration
                    diags.push(d);
                    *visited = MAX_INSTANCES_PER_DECL + 2;
                    return;
                }
            }
        }
        env.idx = *emitted as i64;
        *emitted += 1;
        if let Err(d) = emit_instance(decl, env, flat) {
            diags.push(d);
        }
        return;
    }
    let range = &decl.foreach[depth];
    let (lo, hi) = match (eval(&range.lo, env), eval(&range.hi, env)) {
        (Ok(lo), Ok(hi)) => (lo, hi),
        (Err(d), _) | (_, Err(d)) => {
            // bounds that error once error for every surrounding iteration;
            // report once and halt this declaration's expansion
            diags.push(d);
            *visited = MAX_INSTANCES_PER_DECL + 2;
            return;
        }
    };
    for v in lo..hi {
        // count every loop iteration, not just leaf visits — an enormous
        // outer range over an empty inner range must still terminate
        *visited += 1;
        if *visited > MAX_INSTANCES_PER_DECL {
            if *visited == MAX_INSTANCES_PER_DECL + 1 {
                diags.push(Diagnostic::error(
                    decl.span,
                    format!(
                        "declaration iterates more than {MAX_INSTANCES_PER_DECL} times"
                    ),
                ));
            }
            return;
        }
        env.vars.push((range.var.node.clone(), v));
        expand_ranges(decl, depth + 1, env, emitted, visited, flat, diags);
        env.vars.pop();
        if *visited > MAX_INSTANCES_PER_DECL {
            return; // capped; error already reported
        }
    }
}

fn emit_instance(
    decl: &Decl,
    env: &Env<'_>,
    flat: &mut Flat,
) -> std::result::Result<(), Diagnostic> {
    let name_of = |t: &Template| -> std::result::Result<Spanned<String>, Diagnostic> {
        Ok(Spanned::new(render(t, env)?, t.span))
    };
    let latency_of = |t: &Template| -> std::result::Result<Latency, Diagnostic> {
        let rendered = render(t, env)?;
        Latency::parse(&rendered).map_err(|e| {
            Diagnostic::error(t.span, format!("bad latency expression {rendered:?}: {e:#}"))
        })
    };
    match &decl.body {
        DeclBody::Stage { name, latency } => flat.objects.push(FlatObject {
            name: name_of(name)?,
            kind: FlatObjKind::Stage { latency: latency_of(latency)? },
        }),
        DeclBody::ExecuteStage { name } => flat
            .objects
            .push(FlatObject { name: name_of(name)?, kind: FlatObjKind::ExecuteStage }),
        DeclBody::FunctionalUnit { name, container, latency, ops } => {
            let container = match container {
                Some(c) => Some(name_of(c)?),
                None => None,
            };
            flat.objects.push(FlatObject {
                name: name_of(name)?,
                kind: FlatObjKind::FunctionalUnit {
                    container,
                    latency: latency_of(latency)?,
                    ops: ops.clone(),
                },
            });
        }
        DeclBody::RegisterFile { name, prefix, count } => flat.objects.push(FlatObject {
            name: name_of(name)?,
            kind: FlatObjKind::RegisterFile {
                prefix: render(prefix, env)?,
                count: eval(count, env)?,
            },
        }),
        DeclBody::Memory {
            name,
            read_latency,
            write_latency,
            port_width,
            max_concurrent,
            base,
            words,
        } => flat.objects.push(FlatObject {
            name: name_of(name)?,
            kind: FlatObjKind::Memory {
                read_latency: latency_of(read_latency)?,
                write_latency: latency_of(write_latency)?,
                port_width: eval(port_width, env)?,
                max_concurrent: eval(max_concurrent, env)?,
                base: eval(base, env)?,
                words: eval(words, env)?,
            },
        }),
        DeclBody::Forward { from, to } => flat.edges.push(FlatEdge {
            kind: EdgeKind::Forward,
            a: name_of(from)?,
            b: name_of(to)?,
        }),
        DeclBody::Contains { parent, child } => flat.edges.push(FlatEdge {
            kind: EdgeKind::Contains,
            a: name_of(parent)?,
            b: name_of(child)?,
        }),
        DeclBody::Reads { fu, rf } => flat.edges.push(FlatEdge {
            kind: EdgeKind::Reads,
            a: name_of(fu)?,
            b: name_of(rf)?,
        }),
        DeclBody::Writes { fu, rf } => flat.edges.push(FlatEdge {
            kind: EdgeKind::Writes,
            a: name_of(fu)?,
            b: name_of(rf)?,
        }),
        DeclBody::MemRead { fu, mem } => flat.edges.push(FlatEdge {
            kind: EdgeKind::MemRead,
            a: name_of(fu)?,
            b: name_of(mem)?,
        }),
        DeclBody::MemWrite { fu, mem } => flat.edges.push(FlatEdge {
            kind: EdgeKind::MemWrite,
            a: name_of(fu)?,
            b: name_of(mem)?,
        }),
    }
    Ok(())
}

// ---- diagram construction --------------------------------------------------

/// Build the ACADL object diagram from a validated [`Flat`] description.
/// Call [`validate`] first: this function assumes names resolve, kinds
/// match, and numeric attributes are in range.
pub fn build_diagram(flat: &Flat) -> Result<Diagram> {
    let mut d = Diagram::new(flat.name.clone());
    if let Some(isa) = &flat.isa {
        for op in isa {
            d.op(&op.node);
        }
    }
    let fetch = flat.fetch.as_ref().context("description has no [fetch] section")?;
    let (imem, ifs) = d.add_fetch(
        &fetch.imem,
        fetch.read_latency as u64,
        fetch.port_width as u32,
        &fetch.ifs,
        fetch.ifs_latency as u64,
        fetch.issue_buffer as u32,
    );

    let mut ids: HashMap<&str, ObjId> = HashMap::new();
    ids.insert(fetch.imem.as_str(), imem);
    ids.insert(fetch.ifs.as_str(), ifs);

    // container of each functional unit: `in = "..."` merged with explicit
    // [[contains]] edges (validate guarantees exactly one per FU)
    let mut containers: HashMap<&str, &str> = HashMap::new();
    for o in &flat.objects {
        if let FlatObjKind::FunctionalUnit { container: Some(c), .. } = &o.kind {
            containers.insert(o.name.node.as_str(), c.node.as_str());
        }
    }
    for e in &flat.edges {
        if e.kind == EdgeKind::Contains {
            containers.insert(e.b.node.as_str(), e.a.node.as_str());
        }
    }

    for o in &flat.objects {
        let id = match &o.kind {
            FlatObjKind::Stage { latency } => d.add_stage(&o.name.node, latency.clone()),
            FlatObjKind::ExecuteStage => d.add_execute_stage(&o.name.node),
            FlatObjKind::FunctionalUnit { latency, ops, .. } => {
                let es_name = containers
                    .get(o.name.node.as_str())
                    .with_context(|| format!("functional unit {} has no container", o.name.node))?;
                let es = *ids
                    .get(es_name)
                    .with_context(|| format!("container {es_name} not declared before {}", o.name.node))?;
                let op_names: Vec<&str> = ops.iter().map(|s| s.node.as_str()).collect();
                d.add_fu(es, &o.name.node, latency.clone(), &op_names)
            }
            FlatObjKind::RegisterFile { prefix, count } => {
                let (rf, _regs) = d.add_regfile(&o.name.node, prefix, *count as u32);
                rf
            }
            FlatObjKind::Memory {
                read_latency,
                write_latency,
                port_width,
                max_concurrent,
                base,
                words,
            } => d.add_memory(
                &o.name.node,
                read_latency.clone(),
                write_latency.clone(),
                *port_width as u32,
                *max_concurrent as u32,
                *base as u64,
                *words as u64,
            ),
        };
        ids.insert(o.name.node.as_str(), id);
    }

    for e in &flat.edges {
        let a = *ids
            .get(e.a.node.as_str())
            .with_context(|| format!("unknown object {} in edge", e.a.node))?;
        let b = *ids
            .get(e.b.node.as_str())
            .with_context(|| format!("unknown object {} in edge", e.b.node))?;
        match e.kind {
            EdgeKind::Forward => d.forward(a, b),
            EdgeKind::Contains => {} // consumed by add_fu above
            EdgeKind::Reads => d.fu_reads(a, b),
            EdgeKind::Writes => d.fu_writes(a, b),
            EdgeKind::MemRead => d.mem_reads(a, b),
            EdgeKind::MemWrite => d.mem_writes(a, b),
        }
    }

    d.finalize().with_context(|| format!("finalizing described diagram {}", flat.name))?;
    Ok(d)
}

// ---- mapper binding --------------------------------------------------------

/// A compiled description bound to its mapper family.
#[derive(Clone)]
pub enum CompiledModel {
    /// Scalar-mapped systolic model.
    Systolic(Arc<Systolic>),
    /// Fused-tensor UltraTrail model.
    UltraTrail(Arc<UltraTrail>),
    /// Tiled-GEMM Gemmini model.
    Gemmini(Arc<Gemmini>),
    /// Plasticine grid model.
    Plasticine(Arc<Plasticine>),
}

// the accel structs carry closures/interners and derive no Debug; a short
// summary is enough for diagnostics
impl std::fmt::Debug for CompiledModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CompiledModel::{} ({})", self.family(), self.diagram().name)
    }
}

impl CompiledModel {
    /// The mapper family name.
    pub fn family(&self) -> &'static str {
        match self {
            CompiledModel::Systolic(_) => "scalar",
            CompiledModel::UltraTrail(_) => "tensor_op",
            CompiledModel::Gemmini(_) => "gemm_tile",
            CompiledModel::Plasticine(_) => "plasticine",
        }
    }

    /// The compiled diagram.
    pub fn diagram(&self) -> &Diagram {
        match self {
            CompiledModel::Systolic(m) => &m.diagram,
            CompiledModel::UltraTrail(m) => &m.diagram,
            CompiledModel::Gemmini(m) => &m.diagram,
            CompiledModel::Plasticine(m) => &m.diagram,
        }
    }

    /// Instantiate the family's DNN mapper over the compiled diagram.
    pub fn mapper(&self) -> Box<dyn Mapper + Send + Sync> {
        match self {
            CompiledModel::Systolic(m) => Box::new(ScalarMapper::new(Arc::clone(m))),
            CompiledModel::UltraTrail(m) => Box::new(TensorOpMapper::new(Arc::clone(m))),
            CompiledModel::Gemmini(m) => Box::new(GemmTileMapper::new(Arc::clone(m))),
            CompiledModel::Plasticine(m) => Box::new(PlasticineMapper::new(Arc::clone(m))),
        }
    }
}

/// The result of compiling one description.
#[derive(Debug, Clone)]
pub struct CompiledArch {
    // CompiledModel has a manual Debug impl (see above)
    /// Architecture name (from `[arch] name`).
    pub name: String,
    /// The mapper-bound model.
    pub model: CompiledModel,
}

fn param_i64(flat: &Flat, name: &str) -> Option<i64> {
    flat.params.get(name).copied()
}

fn param_u32(flat: &Flat, name: &str) -> Option<u32> {
    param_i64(flat, name).and_then(|v| u32::try_from(v).ok())
}

fn param_u64(flat: &Flat, name: &str) -> Option<u64> {
    param_i64(flat, name).and_then(|v| u64::try_from(v).ok())
}

fn required_u32(flat: &Flat, name: &str) -> Result<u32> {
    param_u32(flat, name)
        .with_context(|| format!("mapper family needs positive integer parameter `{name}`"))
}

/// Bind a built diagram to the description's mapper family, reconstructing
/// the family's op/register/memory handles by name.
///
/// NOTE: every parameter this function reads by name must also appear in
/// [`MAPPER_FAMILIES`] for its family, or sweeping it will emit a false
/// "unreferenced sweep parameter" warning.
pub fn bind(flat: &Flat, diagram: Diagram) -> Result<CompiledModel> {
    let fetch = flat.fetch.as_ref().context("description has no [fetch] section")?;
    let family = flat
        .mapper
        .as_ref()
        .context("description has no [mapper] section (family = scalar|tensor_op|gemm_tile|plasticine)")?;
    match family.node.as_str() {
        "scalar" => {
            let mut cfg = SystolicConfig::new(
                required_u32(flat, "rows")?,
                required_u32(flat, "cols")?,
            );
            if let Some(v) = param_u32(flat, "port_width") {
                cfg.port_width = v;
            }
            if let Some(v) = param_u64(flat, "mem_read_latency") {
                cfg.mem_read_latency = v;
            }
            if let Some(v) = param_u64(flat, "mem_write_latency") {
                cfg.mem_write_latency = v;
            }
            if let Some(v) = param_u32(flat, "mem_concurrency") {
                cfg.mem_concurrency = v;
            }
            cfg.imem_port_width = fetch.port_width as u32;
            cfg.issue_buffer = fetch.issue_buffer as u32;
            Ok(CompiledModel::Systolic(Arc::new(Systolic::from_described(diagram, cfg)?)))
        }
        "tensor_op" => {
            let cfg = UltraTrailConfig {
                array_dim: required_u32(flat, "array_dim")?,
                imem_port_width: fetch.port_width as u32,
                issue_buffer: fetch.issue_buffer as u32,
            };
            Ok(CompiledModel::UltraTrail(Arc::new(UltraTrail::from_described(diagram, cfg)?)))
        }
        "gemm_tile" => {
            let dflt = GemminiConfig::default();
            let cfg = GemminiConfig {
                dim: required_u32(flat, "dim")?,
                dram_base_latency: param_u64(flat, "dram_base_latency")
                    .unwrap_or(dflt.dram_base_latency),
                dram_words_per_beat: param_u64(flat, "dram_words_per_beat")
                    .unwrap_or(dflt.dram_words_per_beat),
                dram_row_words: param_u64(flat, "dram_row_words").unwrap_or(dflt.dram_row_words),
                imem_port_width: fetch.port_width as u32,
                issue_buffer: fetch.issue_buffer as u32,
            };
            Ok(CompiledModel::Gemmini(Arc::new(Gemmini::from_described(diagram, cfg)?)))
        }
        "plasticine" => {
            let mut cfg = PlasticineConfig::new(
                required_u32(flat, "rows")?,
                required_u32(flat, "cols")?,
                required_u32(flat, "tile")?,
            );
            if let Some(v) = param_u32(flat, "simd_lanes") {
                cfg.simd_lanes = v;
            }
            if let Some(v) = param_u32(flat, "pipe_depth") {
                cfg.pipe_depth = v;
            }
            if let Some(v) = param_u32(flat, "switch_width") {
                cfg.switch_width = v;
            }
            cfg.imem_port_width = fetch.port_width as u32;
            cfg.issue_buffer = fetch.issue_buffer as u32;
            Ok(CompiledModel::Plasticine(Arc::new(Plasticine::from_described(diagram, cfg)?)))
        }
        other => bail!(
            "unknown mapper family {other:?} (scalar|tensor_op|gemm_tile|plasticine)"
        ),
    }
}

// ---- front doors -----------------------------------------------------------

/// Parse + expand + validate, returning the flat form (when parseable) and
/// every diagnostic. This is what `acadl-perf check` drives.
pub fn check_source(src: &str) -> (Option<Flat>, Vec<Diagnostic>) {
    let desc = match parse(src) {
        Ok(d) => d,
        Err(diag) => return (None, vec![diag]),
    };
    let (flat, mut diags) = expand(&desc);
    diags.extend(validate(&flat));
    (Some(flat), diags)
}

/// Compile a description source to a mapper-bound model, failing with the
/// first diagnostics formatted into the error message.
pub fn compile_source(src: &str, origin: &str) -> Result<CompiledArch> {
    let (flat, diags) = check_source(src);
    let errors: Vec<&Diagnostic> = diags.iter().filter(|d| d.is_error()).collect();
    if !errors.is_empty() {
        let shown: Vec<String> = errors.iter().take(5).map(|d| d.render(origin)).collect();
        bail!(
            "{} error(s) in architecture description:\n{}",
            errors.len(),
            shown.join("\n")
        );
    }
    let flat = flat.context("description did not parse")?;
    let diagram = build_diagram(&flat)?;
    let model = bind(&flat, diagram)?;
    Ok(CompiledArch { name: flat.name.clone(), model })
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::isa::Instruction;

    /// A tiny but complete description (mirror of diagram.rs `tiny()`).
    pub(crate) const TINY: &str = r#"
[arch]
name = "tiny"

[params]
n_regs = 4

[isa]
ops = ["add", "load"]

[fetch]
imem = "imem"
imem_read_latency = 1
imem_port_width = 2
ifs = "ifs"
ifs_latency = 1
issue_buffer = 4

[[execute_stage]]
name = "es0"

[[register_file]]
name = "rf0"
prefix = "r"
count = "n_regs"

[[memory]]
name = "dmem"
read_latency = 4
write_latency = 4
port_width = 2
max_concurrent = 1
base = 0
words = 1024

[[functional_unit]]
name = "alu0"
in = "es0"
latency = 1
ops = ["add", "load"]

[[forward]]
from = "ifs"
to = "es0"

[[reads]]
fu = "alu0"
rf = "rf0"

[[writes]]
fu = "alu0"
rf = "rf0"

[[mem_read]]
fu = "alu0"
mem = "dmem"

[[mem_write]]
fu = "alu0"
mem = "dmem"
"#;

    #[test]
    fn tiny_description_compiles_and_routes() {
        let (flat, diags) = check_source(TINY);
        assert!(diags.iter().all(|d| !d.is_error()), "{diags:?}");
        let d = build_diagram(&flat.unwrap()).unwrap();
        assert_eq!(d.name, "tiny");
        assert_eq!(d.fetch_config().port_width, 2);
        let add = d.lookup_op("add").unwrap();
        let r0 = d.lookup_reg("r0").unwrap();
        let r1 = d.lookup_reg("r1").unwrap();
        let i = Instruction::new(add).reads(&[r0]).writes(&[r1]);
        let route = d.route(&i).unwrap();
        assert_eq!(d.object(route.fu).name, "alu0");
        let load = d.lookup_op("load").unwrap();
        let li = Instruction::new(load).writes(&[r0]).read_mem(&[16]);
        assert!(d.route(&li).unwrap().has_writeback);
    }

    #[test]
    fn foreach_when_and_idx_expand() {
        let src = r#"
[arch]
name = "grid${rows}x${cols}"

[params]
rows = 2
cols = 3

[fetch]
imem = "imem"
imem_read_latency = 1
imem_port_width = 1
ifs = "ifs"
ifs_latency = 1
issue_buffer = 1

[[memory]]
name = "pmu[${r}][${c}]"
read_latency = 1
write_latency = 1
port_width = 1
max_concurrent = 1
base = "idx * 100"
words = 100
foreach = "r in 0..rows, c in 0..cols"
when = "(r + c) % 2 == 1"
"#;
        let desc = parse(src).unwrap();
        let (flat, diags) = expand(&desc);
        assert!(diags.is_empty(), "{diags:?}");
        assert_eq!(flat.name, "grid2x3");
        // checkerboard of a 2x3 grid: (0,1), (1,0), (1,2)
        let names: Vec<&str> = flat.objects.iter().map(|o| o.name.node.as_str()).collect();
        assert_eq!(names, vec!["pmu[0][1]", "pmu[1][0]", "pmu[1][2]"]);
        let bases: Vec<i64> = flat
            .objects
            .iter()
            .map(|o| match &o.kind {
                FlatObjKind::Memory { base, .. } => *base,
                _ => panic!("expected memory"),
            })
            .collect();
        assert_eq!(bases, vec![0, 100, 200]);
    }

    #[test]
    fn sweep_expands_and_diagnoses() {
        let head = "[arch]\nname = \"s${rows}\"\n[params]\nrows = 4\ncols = 4\n";
        // happy path: dims evaluated, cap defaulted
        let d = parse(&format!("{head}[sweep]\nrows = \"2, 4\"\ncols = \"2..7 step 2\"\n"))
            .unwrap();
        let (flat, diags) = expand(&d);
        assert!(diags.iter().all(|d| !d.is_error()), "{diags:?}");
        let s = flat.sweep.unwrap();
        assert_eq!(s.dims[0].values, vec![2, 4]);
        assert_eq!(s.dims[1].values, vec![2, 4, 6]);
        assert_eq!(s.cap, DEFAULT_SWEEP_CAP);
        assert_eq!(s.len_bound(), 6);

        let errors = |src: &str| -> Vec<String> {
            let (_, diags) = expand(&parse(src).unwrap());
            diags.iter().filter(|d| d.is_error()).map(|d| d.message.clone()).collect()
        };
        // unknown swept parameter
        let errs = errors(&format!("{head}[sweep]\nnope = \"1, 2\"\n"));
        assert!(errs.iter().any(|e| e.contains("`nope` is not declared in [params]")), "{errs:?}");
        // empty dimension
        let errs = errors(&format!("{head}[sweep]\nrows = \"4..4\"\n"));
        assert!(errs.iter().any(|e| e.contains("`rows` is empty")), "{errs:?}");
        // bad step
        let errs = errors(&format!("{head}[sweep]\nrows = \"0..4 step 0\"\n"));
        assert!(errs.iter().any(|e| e.contains("step must be >= 1")), "{errs:?}");
        // unknown guard parameter
        let errs = errors(&format!("{head}[sweep]\nrows = \"1, 2\"\nwhen = \"bogus > 0\"\n"));
        assert!(errs.iter().any(|e| e.contains("unknown parameter `bogus` in sweep guard")), "{errs:?}");
        // combinatorial blow-up past the cap
        let errs = errors(&format!("{head}[sweep]\nrows = \"0..100\"\ncols = \"0..100\"\ncap = 64\n"));
        assert!(errs.iter().any(|e| e.contains("exceeding the cap of 64")), "{errs:?}");
        // empty [sweep]
        let errs = errors(&format!("{head}[sweep]\ncap = 10\n"));
        assert!(errs.iter().any(|e| e.contains("declares no dimensions")), "{errs:?}");
        // unreferenced sweep parameter warns (cols is neither templated here
        // nor — in a mapperless description — consumed by a binding... but
        // `cols` is mapper-bound, so use a fresh param to trigger it)
        let src = "[arch]\nname = \"s\"\n[params]\nrev = 0\n[sweep]\nrev = \"0, 1\"\n";
        let (_, diags) = expand(&parse(src).unwrap());
        assert!(
            diags.iter().any(|d| !d.is_error() && d.message.contains("not referenced")),
            "{diags:?}"
        );
    }

    #[test]
    fn family_table_is_the_single_source_of_mapper_params() {
        // the table backs validation, binding, and sweep suppression; pin
        // the family set and each family's required parameters
        let families: Vec<&str> = MAPPER_FAMILIES.iter().map(|(f, _, _)| *f).collect();
        assert_eq!(families, vec!["scalar", "tensor_op", "gemm_tile", "plasticine"]);
        assert_eq!(family_params("scalar").unwrap().0, ["rows", "cols"].as_slice());
        assert_eq!(family_params("tensor_op").unwrap().0, ["array_dim"].as_slice());
        assert_eq!(family_params("gemm_tile").unwrap().0, ["dim"].as_slice());
        assert_eq!(
            family_params("plasticine").unwrap().0,
            ["rows", "cols", "tile"].as_slice()
        );
        assert!(family_params("warp_drive").is_none());
    }

    #[test]
    fn expansion_errors_carry_spans() {
        let src = "[arch]\nname = \"x${missing}\"\n";
        let desc = parse(src).unwrap();
        let (_, diags) = expand(&desc);
        assert!(diags.iter().any(|d| d.message.contains("unknown parameter `missing`")));
        assert_eq!(diags[0].span.line, 2);
    }

    #[test]
    fn compile_source_reports_diagnostics() {
        let e = compile_source("[arch]\nname = \"x${missing}\"\n", "inline").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("inline:2:"), "{msg}");
        assert!(msg.contains("unknown parameter"), "{msg}");
    }
}
