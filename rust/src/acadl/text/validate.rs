//! Semantic validation of expanded descriptions: every check reports a
//! [`Diagnostic`] with the span of the offending declaration, so
//! `acadl-perf check` can print `file:line:col: error: ...` lines.
//!
//! Checked here (errors unless noted):
//! - unknown ops: functional-unit `ops` not declared in `[isa]`, and
//!   (warning) declared ops no functional unit processes;
//! - dangling routes: edges naming objects that do not exist, and edges
//!   whose endpoint kinds are wrong (`reads` to a memory, `forward` into a
//!   register file, ...);
//! - containment: cycles, functional units with zero or multiple
//!   containers, non-ES containers, containers declared after the unit;
//! - structure: duplicate object/register names, overlapping memory address
//!   ranges, out-of-range numeric attributes, execute stages unreachable
//!   from the fetch stage, (warning) cyclic forward graphs;
//! - the `[mapper]` binding: unknown family, missing family parameters.
//!
//! `[sweep]` diagnostics (unknown swept parameters, empty dimensions,
//! combinatorial blow-ups, guard name resolution) are reported during
//! expansion — see `expand_sweep` in [`super::compile`] — because they
//! need the template-level AST, which the flattened form no longer has.

use std::collections::{HashMap, HashSet, VecDeque};

use super::ast::Span;
use super::compile::{EdgeKind, Flat, FlatObjKind};
use super::Diagnostic;

/// Validate an expanded description. Returns all diagnostics (errors and
/// warnings); compilation is safe iff none is an error.
pub fn validate(flat: &Flat) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let fetch_names: Vec<&str> = match &flat.fetch {
        Some(f) => vec![f.imem.as_str(), f.ifs.as_str()],
        None => {
            diags.push(Diagnostic::error(
                Span::default(),
                "missing [fetch] section (imem/ifs front-end is required)",
            ));
            Vec::new()
        }
    };

    // compilation narrows these to u32; out-of-range must be a diagnostic,
    // not a silent truncation
    const U32_MAX: i64 = u32::MAX as i64;
    if let Some(f) = &flat.fetch {
        if f.imem == f.ifs {
            diags.push(Diagnostic::error(
                f.span,
                format!("imem and ifs must have distinct names (both are `{}`)", f.imem),
            ));
        }
        if f.read_latency < 0 {
            diags.push(Diagnostic::error(f.span, "imem_read_latency must be >= 0"));
        }
        if !(1..=U32_MAX).contains(&f.port_width) {
            diags.push(Diagnostic::error(f.span, "imem_port_width must be in 1..=2^32-1"));
        }
        if f.ifs_latency < 0 {
            diags.push(Diagnostic::error(f.span, "ifs_latency must be >= 0"));
        }
        if !(1..=U32_MAX).contains(&f.issue_buffer) {
            diags.push(Diagnostic::error(f.span, "issue_buffer must be in 1..=2^32-1"));
        }
    }

    // ---- object table + duplicates ------------------------------------------
    let mut kind_of: HashMap<&str, &FlatObjKind> = HashMap::new();
    let mut order_of: HashMap<&str, usize> = HashMap::new();
    for (i, o) in flat.objects.iter().enumerate() {
        let name = o.name.node.as_str();
        if fetch_names.contains(&name) {
            diags.push(Diagnostic::error(
                o.name.span,
                format!("object `{name}` clashes with a [fetch] object name"),
            ));
            continue;
        }
        if name == "writeBack" {
            diags.push(Diagnostic::warning(
                o.name.span,
                "`writeBack` shadows the implicit write-back pseudo-object",
            ));
        }
        if kind_of.insert(name, &o.kind).is_some() {
            diags.push(Diagnostic::error(
                o.name.span,
                format!("duplicate object name `{name}`"),
            ));
        } else {
            order_of.insert(name, i);
        }
    }

    // ---- numeric attribute ranges -------------------------------------------
    for o in &flat.objects {
        match &o.kind {
            FlatObjKind::Memory { port_width, max_concurrent, base, words, .. } => {
                if !(1..=U32_MAX).contains(port_width) {
                    diags.push(Diagnostic::error(
                        o.name.span,
                        "memory port_width must be in 1..=2^32-1",
                    ));
                }
                if !(1..=U32_MAX).contains(max_concurrent) {
                    diags.push(Diagnostic::error(
                        o.name.span,
                        "memory max_concurrent must be in 1..=2^32-1",
                    ));
                }
                if *base < 0 || *words < 0 {
                    diags.push(Diagnostic::error(
                        o.name.span,
                        "memory base/words must be >= 0",
                    ));
                }
            }
            FlatObjKind::RegisterFile { count, .. } => {
                if *count < 0 || *count > (1 << 20) {
                    diags.push(Diagnostic::error(
                        o.name.span,
                        format!("register file count {count} out of range"),
                    ));
                }
            }
            _ => {}
        }
    }

    // ---- register name collisions across register files ---------------------
    let mut reg_names: HashMap<String, &str> = HashMap::new();
    for o in &flat.objects {
        if let FlatObjKind::RegisterFile { prefix, count } = &o.kind {
            for i in 0..(*count).clamp(0, 1 << 20) {
                let reg = format!("{prefix}{i}");
                if let Some(other) = reg_names.insert(reg.clone(), o.name.node.as_str()) {
                    if other != o.name.node.as_str() {
                        diags.push(Diagnostic::error(
                            o.name.span,
                            format!(
                                "register `{reg}` of `{}` is also declared by `{other}`",
                                o.name.node
                            ),
                        ));
                        break;
                    }
                }
            }
        }
    }

    // ---- isa / op checks ----------------------------------------------------
    if let Some(isa) = &flat.isa {
        let mut declared: HashSet<&str> = HashSet::new();
        for op in isa {
            if !declared.insert(op.node.as_str()) {
                diags.push(Diagnostic::warning(
                    op.span,
                    format!("op `{}` declared twice in [isa]", op.node),
                ));
            }
        }
        let mut processed: HashSet<&str> = HashSet::new();
        for o in &flat.objects {
            if let FlatObjKind::FunctionalUnit { ops, .. } = &o.kind {
                for op in ops {
                    if !declared.contains(op.node.as_str()) {
                        diags.push(Diagnostic::error(
                            op.span,
                            format!("unknown op `{}` (not declared in [isa])", op.node),
                        ));
                    }
                    processed.insert(op.node.as_str());
                }
            }
        }
        for op in isa {
            if !processed.contains(op.node.as_str()) {
                diags.push(Diagnostic::warning(
                    op.span,
                    format!("op `{}` is not processed by any functional unit", op.node),
                ));
            }
        }
    }
    for o in &flat.objects {
        if let FlatObjKind::FunctionalUnit { ops, .. } = &o.kind {
            if ops.is_empty() {
                diags.push(Diagnostic::warning(
                    o.name.span,
                    format!("functional unit `{}` processes no ops", o.name.node),
                ));
            }
        }
    }

    // ---- edge endpoint resolution + kind checks -----------------------------
    let resolve = |name: &str| -> bool {
        kind_of.contains_key(name) || fetch_names.contains(&name)
    };
    let is_forwardable = |name: &str| -> bool {
        // the IFS plus pipeline/execute stages can appear in forward edges
        fetch_names.get(1).is_some_and(|ifs| *ifs == name)
            || matches!(
                kind_of.get(name),
                Some(FlatObjKind::Stage { .. }) | Some(FlatObjKind::ExecuteStage)
            )
    };
    for e in &flat.edges {
        for end in [&e.a, &e.b] {
            if !resolve(&end.node) {
                diags.push(Diagnostic::error(
                    end.span,
                    format!("dangling route: no object named `{}`", end.node),
                ));
            }
        }
        if !resolve(&e.a.node) || !resolve(&e.b.node) {
            continue; // kind checks need both endpoints
        }
        match e.kind {
            EdgeKind::Forward => {
                for end in [&e.a, &e.b] {
                    if !is_forwardable(&end.node) {
                        diags.push(Diagnostic::error(
                            end.span,
                            format!(
                                "forward edge endpoint `{}` must be the fetch stage, a pipeline \
                                 stage, or an execute stage",
                                end.node
                            ),
                        ));
                    }
                }
            }
            EdgeKind::Contains => {} // containment checks below
            EdgeKind::Reads | EdgeKind::Writes => {
                if !matches!(kind_of.get(e.a.node.as_str()), Some(FlatObjKind::FunctionalUnit { .. }))
                {
                    diags.push(Diagnostic::error(
                        e.a.span,
                        format!("`{}` must be a functional unit", e.a.node),
                    ));
                }
                if !matches!(kind_of.get(e.b.node.as_str()), Some(FlatObjKind::RegisterFile { .. }))
                {
                    diags.push(Diagnostic::error(
                        e.b.span,
                        format!("`{}` must be a register file", e.b.node),
                    ));
                }
            }
            EdgeKind::MemRead | EdgeKind::MemWrite => {
                if !matches!(kind_of.get(e.a.node.as_str()), Some(FlatObjKind::FunctionalUnit { .. }))
                {
                    diags.push(Diagnostic::error(
                        e.a.span,
                        format!("`{}` must be a functional unit", e.a.node),
                    ));
                }
                if !matches!(kind_of.get(e.b.node.as_str()), Some(FlatObjKind::Memory { .. })) {
                    diags.push(Diagnostic::error(
                        e.b.span,
                        format!("`{}` must be a memory", e.b.node),
                    ));
                }
            }
        }
    }

    // ---- containment: build the graph, find cycles, then kind-check ---------
    // edges parent -> child, from both `in = "..."` attributes and explicit
    // [[contains]] declarations
    let mut contain_edges: Vec<(&str, &str, Span)> = Vec::new();
    for o in &flat.objects {
        if let FlatObjKind::FunctionalUnit { container: Some(c), .. } = &o.kind {
            contain_edges.push((c.node.as_str(), o.name.node.as_str(), c.span));
        }
    }
    for e in &flat.edges {
        if e.kind == EdgeKind::Contains {
            contain_edges.push((e.a.node.as_str(), e.b.node.as_str(), e.a.span));
        }
    }
    if let Some((cycle, span)) = find_cycle(&contain_edges) {
        diags.push(Diagnostic::error(
            span,
            format!("containment cycle: {}", cycle.join(" -> ")),
        ));
    } else {
        // acyclic: per-edge kind checks and per-FU container counts
        for &(parent, child, span) in &contain_edges {
            if !resolve(parent) {
                diags.push(Diagnostic::error(
                    span,
                    format!("dangling route: no object named `{parent}`"),
                ));
                continue;
            }
            if !matches!(kind_of.get(parent), Some(FlatObjKind::ExecuteStage)) {
                diags.push(Diagnostic::error(
                    span,
                    format!("container `{parent}` must be an execute stage"),
                ));
            }
            if resolve(child)
                && !matches!(kind_of.get(child), Some(FlatObjKind::FunctionalUnit { .. }))
            {
                diags.push(Diagnostic::error(
                    span,
                    format!("contained object `{child}` must be a functional unit"),
                ));
            }
            // compilation creates objects in declaration order
            if let (Some(&pi), Some(&ci)) = (order_of.get(parent), order_of.get(child)) {
                if pi > ci {
                    diags.push(Diagnostic::error(
                        span,
                        format!(
                            "execute stage `{parent}` must be declared before the functional \
                             unit `{child}` it contains"
                        ),
                    ));
                }
            }
        }
        for o in &flat.objects {
            if let FlatObjKind::FunctionalUnit { .. } = &o.kind {
                let n = contain_edges
                    .iter()
                    .filter(|(_, c, _)| *c == o.name.node.as_str())
                    .count();
                if n == 0 {
                    diags.push(Diagnostic::error(
                        o.name.span,
                        format!(
                            "functional unit `{}` is not contained in any execute stage (set \
                             `in = ...` or add a [[contains]] edge)",
                            o.name.node
                        ),
                    ));
                } else if n > 1 {
                    diags.push(Diagnostic::error(
                        o.name.span,
                        format!("functional unit `{}` has {n} containers (needs exactly 1)", o.name.node),
                    ));
                }
            }
        }
    }

    // ---- overlapping memory address ranges ----------------------------------
    let mut ranges: Vec<(i64, i64, &str, Span)> = flat
        .objects
        .iter()
        .filter_map(|o| match &o.kind {
            FlatObjKind::Memory { base, words, .. } if *words > 0 => {
                Some((*base, base.saturating_add(*words), o.name.node.as_str(), o.name.span))
            }
            _ => None,
        })
        .collect();
    ranges.sort_by_key(|r| r.0);
    for w in ranges.windows(2) {
        if w[0].1 > w[1].0 {
            diags.push(Diagnostic::error(
                w[1].3,
                format!("memory `{}` overlaps the address range of `{}`", w[1].2, w[0].2),
            ));
        }
    }

    // ---- forward reachability + cycles --------------------------------------
    if let Some(f) = &flat.fetch {
        let fwd: Vec<(&str, &str, Span)> = flat
            .edges
            .iter()
            .filter(|e| e.kind == EdgeKind::Forward)
            .map(|e| (e.a.node.as_str(), e.b.node.as_str(), e.a.span))
            .collect();
        let mut reach: HashSet<&str> = HashSet::new();
        let mut queue: VecDeque<&str> = VecDeque::new();
        reach.insert(f.ifs.as_str());
        queue.push_back(f.ifs.as_str());
        while let Some(cur) = queue.pop_front() {
            for &(a, b, _) in &fwd {
                if a == cur && reach.insert(b) {
                    queue.push_back(b);
                }
            }
        }
        let contained_es: HashSet<&str> =
            contain_edges.iter().map(|(parent, _, _)| *parent).collect();
        for o in &flat.objects {
            if matches!(o.kind, FlatObjKind::ExecuteStage)
                && contained_es.contains(o.name.node.as_str())
                && !reach.contains(o.name.node.as_str())
            {
                diags.push(Diagnostic::error(
                    o.name.span,
                    format!(
                        "no forward path from fetch stage `{}` to execute stage `{}`",
                        f.ifs, o.name.node
                    ),
                ));
            }
        }
        if let Some((cycle, span)) = find_cycle(&fwd) {
            diags.push(Diagnostic::warning(
                span,
                format!("forward graph contains a cycle: {}", cycle.join(" -> ")),
            ));
        }
    }

    // ---- mapper binding -----------------------------------------------------
    match &flat.mapper {
        None => diags.push(Diagnostic::warning(
            Span::default(),
            "no [mapper] section; the description can be checked but not estimated",
        )),
        Some(family) => {
            // required parameters come from the shared family table
            // (`compile::MAPPER_FAMILIES`) so validation, binding, and the
            // sweep checks can never disagree
            let required: &[&str] = match super::compile::family_params(&family.node) {
                Some((required, _)) => required,
                None => {
                    diags.push(Diagnostic::error(
                        family.span,
                        format!(
                            "unknown mapper family `{}` \
                             (scalar|tensor_op|gemm_tile|plasticine)",
                            family.node
                        ),
                    ));
                    &[]
                }
            };
            for p in required {
                match flat.params.get(*p) {
                    Some(v) if *v >= 1 => {}
                    Some(v) => diags.push(Diagnostic::error(
                        family.span,
                        format!("mapper family `{}` needs parameter `{p}` >= 1 (got {v})", family.node),
                    )),
                    None => diags.push(Diagnostic::error(
                        family.span,
                        format!("mapper family `{}` needs parameter `{p}`", family.node),
                    )),
                }
            }
        }
    }

    diags
}

/// Find a cycle in a name graph; returns the cycle path (first node
/// repeated at the end) and the span of one participating edge.
fn find_cycle(edges: &[(&str, &str, Span)]) -> Option<(Vec<String>, Span)> {
    let mut adj: HashMap<&str, Vec<(&str, Span)>> = HashMap::new();
    for (a, b, s) in edges {
        adj.entry(a).or_default().push((b, *s));
    }
    let mut state: HashMap<&str, u8> = HashMap::new(); // 1 = on stack, 2 = done
    for &start in adj.keys() {
        if state.contains_key(start) {
            continue;
        }
        // iterative DFS keeping the path for cycle reporting
        let mut path: Vec<(&str, usize)> = vec![(start, 0)];
        state.insert(start, 1);
        while let Some(top) = path.last_mut() {
            let node = top.0;
            let succs = adj.get(node).map(Vec::as_slice).unwrap_or(&[]);
            if top.1 >= succs.len() {
                state.insert(node, 2);
                path.pop();
                continue;
            }
            let (succ, span) = succs[top.1];
            top.1 += 1;
            match state.get(succ) {
                Some(1) => {
                    // found: slice the path from succ onward
                    let pos = path.iter().position(|(n, _)| *n == succ).unwrap_or(0);
                    let mut cycle: Vec<String> =
                        path[pos..].iter().map(|(n, _)| n.to_string()).collect();
                    cycle.push(succ.to_string());
                    return Some((cycle, span));
                }
                Some(_) => {}
                None => {
                    state.insert(succ, 1);
                    path.push((succ, 0));
                }
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::super::compile::check_source;
    use super::super::Severity;

    fn errors_of(src: &str) -> Vec<String> {
        let (_, diags) = check_source(src);
        diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| format!("{}:{} {}", d.span.line, d.span.col, d.message))
            .collect()
    }

    const HEAD: &str = r#"
[arch]
name = "t"

[isa]
ops = ["add"]

[fetch]
imem = "imem"
imem_read_latency = 1
imem_port_width = 1
ifs = "ifs"
ifs_latency = 1
issue_buffer = 1
"#;

    #[test]
    fn unknown_op_is_reported_with_span() {
        let src = format!(
            "{HEAD}\n[[execute_stage]]\nname = \"es\"\n\n[[functional_unit]]\nname = \"fu\"\n\
             in = \"es\"\nlatency = 1\nops = [\"add\", \"frobnicate\"]\n\n\
             [[forward]]\nfrom = \"ifs\"\nto = \"es\"\n"
        );
        let errs = errors_of(&src);
        assert!(
            errs.iter().any(|e| e.contains("unknown op `frobnicate`")),
            "{errs:?}"
        );
    }

    #[test]
    fn dangling_route_is_reported() {
        let src = format!("{HEAD}\n[[forward]]\nfrom = \"ifs\"\nto = \"nowhere\"\n");
        let errs = errors_of(&src);
        assert!(errs.iter().any(|e| e.contains("dangling route: no object named `nowhere`")), "{errs:?}");
    }

    #[test]
    fn containment_cycle_is_reported() {
        let src = format!(
            "{HEAD}\n[[execute_stage]]\nname = \"a\"\n\n[[execute_stage]]\nname = \"b\"\n\n\
             [[contains]]\nparent = \"a\"\nchild = \"b\"\n\n\
             [[contains]]\nparent = \"b\"\nchild = \"a\"\n"
        );
        let errs = errors_of(&src);
        assert!(errs.iter().any(|e| e.contains("containment cycle")), "{errs:?}");
    }

    #[test]
    fn uncontained_fu_and_wrong_kinds_are_reported() {
        let src = format!(
            "{HEAD}\n[[functional_unit]]\nname = \"orphan\"\nlatency = 1\nops = [\"add\"]\n\n\
             [[register_file]]\nname = \"rf\"\nprefix = \"r\"\ncount = 1\n\n\
             [[reads]]\nfu = \"rf\"\nrf = \"orphan\"\n"
        );
        let errs = errors_of(&src);
        assert!(errs.iter().any(|e| e.contains("`orphan` is not contained")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("`rf` must be a functional unit")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("`orphan` must be a register file")), "{errs:?}");
    }

    #[test]
    fn overlapping_memories_and_duplicates_are_reported() {
        let src = format!(
            "{HEAD}\n[[memory]]\nname = \"m1\"\nread_latency = 1\nwrite_latency = 1\n\
             port_width = 1\nmax_concurrent = 1\nbase = 0\nwords = 100\n\n\
             [[memory]]\nname = \"m2\"\nread_latency = 1\nwrite_latency = 1\n\
             port_width = 1\nmax_concurrent = 1\nbase = 50\nwords = 100\n\n\
             [[memory]]\nname = \"m1\"\nread_latency = 1\nwrite_latency = 1\n\
             port_width = 0\nmax_concurrent = 1\nbase = 500\nwords = 10\n"
        );
        let errs = errors_of(&src);
        assert!(errs.iter().any(|e| e.contains("overlaps")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("duplicate object name `m1`")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("port_width must be in 1..=2^32-1")), "{errs:?}");
    }

    #[test]
    fn unreachable_execute_stage_is_reported() {
        let src = format!(
            "{HEAD}\n[[execute_stage]]\nname = \"es\"\n\n[[functional_unit]]\nname = \"fu\"\n\
             in = \"es\"\nlatency = 1\nops = [\"add\"]\n"
        );
        let errs = errors_of(&src);
        assert!(errs.iter().any(|e| e.contains("no forward path")), "{errs:?}");
    }

    #[test]
    fn mapper_family_checks() {
        let src = format!("{HEAD}\n[mapper]\nfamily = \"warp_drive\"\n");
        let errs = errors_of(&src);
        assert!(errs.iter().any(|e| e.contains("unknown mapper family")), "{errs:?}");
        let src = format!("{HEAD}\n[mapper]\nfamily = \"scalar\"\n");
        let errs = errors_of(&src);
        assert!(errs.iter().any(|e| e.contains("needs parameter `rows`")), "{errs:?}");
    }

    #[test]
    fn clean_description_has_no_errors() {
        let (_, diags) = check_source(super::super::compile::tests::TINY);
        assert!(diags.iter().all(|d| d.severity != Severity::Error), "{diags:?}");
    }
}
