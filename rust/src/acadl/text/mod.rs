//! Textual ACADL frontend: parse, validate, and compile architecture
//! descriptions from TOML-flavored files (see `arch/README.md` for the
//! grammar and `arch/*.toml` for the four paper architectures).
//!
//! Pipeline:
//!
//! ```text
//! source ──parser──▶ Description (template AST)
//!        ──expand──▶ Flat (objects/edges after foreach/when/${} expansion)
//!        ──validate▶ Vec<Diagnostic> (unknown ops, dangling routes,
//!                    containment cycles, ... with file/line spans)
//!        ──build───▶ acadl::Diagram
//!        ──bind────▶ CompiledModel (diagram + mapper-family handles)
//! ```
//!
//! [`registry::ArchRegistry`] caches compiled models keyed by description
//! content, so `serve` loops and DSE sweeps never recompile an unchanged
//! description.
//!
//! Descriptions may additionally carry a declarative `[sweep]` section — a
//! design space over their own `[params]` (value lists, `lo..hi [step s]`
//! ranges, `when` guards, a combinatorial `cap`). Compilation ignores it;
//! [`crate::dse`] enumerates it into candidate architectures (see
//! `docs/dse.md`).

pub mod ast;
pub mod compile;
pub mod lexer;
pub mod parser;
pub mod registry;
pub mod validate;

pub use ast::{Description, PExpr, Span, Spanned, Sweep, SweepDim, SweepItem, Template};
pub use compile::{
    check_source, compile_source, CompiledArch, CompiledModel, Flat, FlatSweep, FlatSweepDim,
    DEFAULT_SWEEP_CAP,
};
pub use parser::parse;
pub use registry::ArchRegistry;
pub use validate::validate;

/// How bad a diagnostic is. Errors block compilation; warnings do not.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Blocks compilation.
    Error,
    /// Advisory only.
    Warning,
}

/// One message tied to a source location.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Error or warning.
    pub severity: Severity,
    /// Source location.
    pub span: Span,
    /// Human-readable message.
    pub message: String,
}

impl Diagnostic {
    /// An error diagnostic at `span`.
    pub fn error(span: Span, message: impl Into<String>) -> Self {
        Self { severity: Severity::Error, span, message: message.into() }
    }

    /// A warning diagnostic at `span`.
    pub fn warning(span: Span, message: impl Into<String>) -> Self {
        Self { severity: Severity::Warning, span, message: message.into() }
    }

    /// True for errors.
    pub fn is_error(&self) -> bool {
        self.severity == Severity::Error
    }

    /// Render as `origin:line:col: severity: message` (the `acadl-perf
    /// check` output format).
    pub fn render(&self, origin: &str) -> String {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        format!("{origin}:{}:{}: {sev}: {}", self.span.line, self.span.col, self.message)
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        write!(f, "{}:{}: {sev}: {}", self.span.line, self.span.col, self.message)
    }
}
