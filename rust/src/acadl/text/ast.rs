//! AST of the textual ACADL description language.
//!
//! A description is a TOML-flavored document (see `arch/README.md`) whose
//! declarations may be *templates*: replicated over integer index ranges
//! (`foreach`), filtered by guards (`when`), with `${expr}` interpolation in
//! names and latency strings. [`PExpr`] is the integer expression language of
//! parameters, loop indices, and the per-declaration ordinal `idx`;
//! instruction-immediates (`immN`) never appear here — they stay inside
//! latency strings and are parsed by [`crate::acadl::latency::Expr`] after
//! `${}` substitution.
//!
//! Every node that can produce a diagnostic carries a [`Span`]. Spans are
//! deliberately **ignored by equality** (`Span::eq` is always true) so the
//! pretty-print → parse round-trip property can compare whole ASTs
//! structurally.

use std::fmt::{self, Write as _};

/// A source position (1-based line and column). Equality is vacuous: two
/// spans always compare equal so AST comparisons ignore positions.
#[derive(Debug, Clone, Copy, Default, Eq)]
pub struct Span {
    /// 1-based line.
    pub line: u32,
    /// 1-based column.
    pub col: u32,
}

impl PartialEq for Span {
    fn eq(&self, _other: &Self) -> bool {
        true
    }
}

impl Span {
    /// A span at `line`:`col`.
    pub fn new(line: u32, col: u32) -> Self {
        Self { line, col }
    }
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.line, self.col)
    }
}

/// A value plus the source span it came from.
#[derive(Debug, Clone, PartialEq)]
pub struct Spanned<T> {
    /// The wrapped value.
    pub node: T,
    /// Where it came from.
    pub span: Span,
}

impl<T> Spanned<T> {
    /// Wrap `node` with `span`.
    pub fn new(node: T, span: Span) -> Self {
        Self { node, span }
    }

    /// Span-less wrapper (used by generators and tests).
    pub fn bare(node: T) -> Self {
        Self { node, span: Span::default() }
    }
}

/// Binary operators of the parameter expression language.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    /// `+`
    Add,
    /// `-`
    Sub,
    /// `*`
    Mul,
    /// `/` (Euclidean)
    Div,
    /// `%` (Euclidean)
    Rem,
    /// `==`
    Eq,
    /// `!=`
    Ne,
    /// `<`
    Lt,
    /// `<=`
    Le,
    /// `>`
    Gt,
    /// `>=`
    Ge,
    /// `&&`
    And,
    /// `||`
    Or,
}

impl BinOp {
    /// Source symbol of the operator.
    pub fn symbol(self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Rem => "%",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::Lt => "<",
            BinOp::Le => "<=",
            BinOp::Gt => ">",
            BinOp::Ge => ">=",
            BinOp::And => "&&",
            BinOp::Or => "||",
        }
    }

    /// Binding strength (higher binds tighter).
    fn precedence(self) -> u8 {
        match self {
            BinOp::Or => 1,
            BinOp::And => 2,
            BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge => 3,
            BinOp::Add | BinOp::Sub => 4,
            BinOp::Mul | BinOp::Div | BinOp::Rem => 5,
        }
    }
}

/// Two-argument builtin functions (same set as the latency language).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Func {
    /// Ceiling division.
    Cdiv,
    /// Maximum.
    Max,
    /// Minimum.
    Min,
}

impl Func {
    /// Source name of the function.
    pub fn name(self) -> &'static str {
        match self {
            Func::Cdiv => "cdiv",
            Func::Max => "max",
            Func::Min => "min",
        }
    }
}

/// Integer parameter expression: constants, parameter/loop-variable
/// references, arithmetic, comparisons (0/1), and `cdiv`/`max`/`min`.
#[derive(Debug, Clone, PartialEq)]
pub enum PExpr {
    /// Integer literal.
    Const(i64),
    /// Parameter or loop-variable reference.
    Var(String),
    /// Unary negation.
    Neg(Box<PExpr>),
    /// Binary operation.
    Bin(BinOp, Box<PExpr>, Box<PExpr>),
    /// Two-argument builtin call.
    Call(Func, Box<PExpr>, Box<PExpr>),
}

impl PExpr {
    /// Evaluate against a variable-lookup function. Division-family
    /// operators error on a zero divisor (a description bug, unlike the
    /// latency language's saturating semantics).
    pub fn eval(&self, lookup: &dyn Fn(&str) -> Option<i64>) -> Result<i64, String> {
        match self {
            PExpr::Const(v) => Ok(*v),
            PExpr::Var(name) => {
                lookup(name).ok_or_else(|| format!("unknown parameter `{name}`"))
            }
            PExpr::Neg(a) => Ok(a.eval(lookup)?.wrapping_neg()),
            PExpr::Bin(op, a, b) => {
                let (x, y) = (a.eval(lookup)?, b.eval(lookup)?);
                Ok(match op {
                    BinOp::Add => x.wrapping_add(y),
                    BinOp::Sub => x.wrapping_sub(y),
                    BinOp::Mul => x.wrapping_mul(y),
                    BinOp::Div => {
                        if y == 0 {
                            return Err("division by zero".into());
                        }
                        x.div_euclid(y)
                    }
                    BinOp::Rem => {
                        if y == 0 {
                            return Err("remainder by zero".into());
                        }
                        x.rem_euclid(y)
                    }
                    BinOp::Eq => i64::from(x == y),
                    BinOp::Ne => i64::from(x != y),
                    BinOp::Lt => i64::from(x < y),
                    BinOp::Le => i64::from(x <= y),
                    BinOp::Gt => i64::from(x > y),
                    BinOp::Ge => i64::from(x >= y),
                    BinOp::And => i64::from(x != 0 && y != 0),
                    BinOp::Or => i64::from(x != 0 || y != 0),
                })
            }
            PExpr::Call(f, a, b) => {
                let (x, y) = (a.eval(lookup)?, b.eval(lookup)?);
                Ok(match f {
                    Func::Cdiv => {
                        if y == 0 {
                            return Err("cdiv by zero".into());
                        }
                        // widen: x + y - 1 can overflow i64 (the other
                        // operators wrap; stay consistent on the way back)
                        ((x as i128 + y as i128 - 1).div_euclid(y as i128)) as i64
                    }
                    Func::Max => x.max(y),
                    Func::Min => x.min(y),
                })
            }
        }
    }

    /// Canonical printing with minimal parentheses; reparsing the output
    /// yields a structurally identical tree.
    fn print(&self, out: &mut String, parent_prec: u8) {
        match self {
            PExpr::Const(v) => {
                let _ = write!(out, "{v}");
            }
            PExpr::Var(name) => out.push_str(name),
            PExpr::Neg(a) => {
                if parent_prec > 6 {
                    out.push('(');
                    out.push('-');
                    a.print(out, 6);
                    out.push(')');
                } else {
                    out.push('-');
                    a.print(out, 6);
                }
            }
            PExpr::Bin(op, a, b) => {
                let p = op.precedence();
                let parens = parent_prec > p;
                if parens {
                    out.push('(');
                }
                // comparisons are non-associative in the grammar (at most
                // one per level), so both children must bind strictly
                // tighter; other operators are left-associative and only
                // need that on the right.
                let is_cmp = matches!(
                    op,
                    BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge
                );
                a.print(out, if is_cmp { p + 1 } else { p });
                let _ = write!(out, " {} ", op.symbol());
                b.print(out, p + 1);
                if parens {
                    out.push(')');
                }
            }
            PExpr::Call(f, a, b) => {
                out.push_str(f.name());
                out.push('(');
                a.print(out, 0);
                out.push_str(", ");
                b.print(out, 0);
                out.push(')');
            }
        }
    }
}

impl fmt::Display for PExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.print(&mut s, 0);
        f.write_str(&s)
    }
}

/// One segment of an interpolated string.
#[derive(Debug, Clone, PartialEq)]
pub enum Segment {
    /// Literal text.
    Lit(String),
    /// A `${...}` hole.
    Expr(PExpr),
}

/// An interpolated string: literal text with `${expr}` holes. Used for
/// object names and latency strings (where the substituted result is parsed
/// by the latency language).
#[derive(Debug, Clone, PartialEq)]
pub struct Template {
    /// Alternating literal and expression segments.
    pub segments: Vec<Segment>,
    /// Source span of the whole template.
    pub span: Span,
}

impl Template {
    /// A template of pure literal text (no holes).
    pub fn lit(text: impl Into<String>) -> Self {
        let text = text.into();
        let segments = if text.is_empty() { Vec::new() } else { vec![Segment::Lit(text)] };
        Self { segments, span: Span::default() }
    }

    /// True if the template has no `${}` holes.
    pub fn is_literal(&self) -> bool {
        self.segments.iter().all(|s| matches!(s, Segment::Lit(_)))
    }

    /// Render with `${expr}` holes evaluated through `lookup`.
    pub fn render(&self, lookup: &dyn Fn(&str) -> Option<i64>) -> Result<String, String> {
        let mut out = String::new();
        for seg in &self.segments {
            match seg {
                Segment::Lit(s) => out.push_str(s),
                Segment::Expr(e) => {
                    let _ = write!(out, "{}", e.eval(lookup)?);
                }
            }
        }
        Ok(out)
    }

    /// Canonical source form (unquoted, `${}`-interpolated).
    pub fn source(&self) -> String {
        let mut out = String::new();
        for seg in &self.segments {
            match seg {
                Segment::Lit(s) => out.push_str(s),
                Segment::Expr(e) => {
                    let _ = write!(out, "${{{e}}}");
                }
            }
        }
        out
    }
}

/// One `var in lo..hi` range of a `foreach` clause (half-open).
#[derive(Debug, Clone, PartialEq)]
pub struct ForRange {
    /// Loop variable.
    pub var: Spanned<String>,
    /// Lower bound (inclusive).
    pub lo: Spanned<PExpr>,
    /// Upper bound (exclusive).
    pub hi: Spanned<PExpr>,
}

/// The fetch front-end section (`[fetch]`).
#[derive(Debug, Clone, PartialEq)]
pub struct Fetch {
    /// Instruction-memory name.
    pub imem: Template,
    /// Instruction-memory read latency.
    pub imem_read_latency: Spanned<PExpr>,
    /// Instructions per fetch transaction.
    pub imem_port_width: Spanned<PExpr>,
    /// Fetch-stage name.
    pub ifs: Template,
    /// Fetch-stage latency.
    pub ifs_latency: Spanned<PExpr>,
    /// Issue-buffer depth.
    pub issue_buffer: Spanned<PExpr>,
    /// Span of the `[fetch]` header.
    pub span: Span,
}

/// A replicable declaration: the body plus its `foreach`/`when` clauses.
#[derive(Debug, Clone, PartialEq)]
pub struct Decl {
    /// The declaration body.
    pub body: DeclBody,
    /// Replication ranges.
    pub foreach: Vec<ForRange>,
    /// Guard expression.
    pub when: Option<Spanned<PExpr>>,
    /// Span of the `[[...]]` header.
    pub span: Span,
}

/// The body of one declaration (object or association edge).
#[derive(Debug, Clone, PartialEq)]
pub enum DeclBody {
    /// A pipeline stage.
    Stage {
        /// Object name.
        name: Template,
        /// Residency latency (latency-language string after `${}`).
        latency: Template,
    },
    /// An execute stage.
    ExecuteStage {
        /// Object name.
        name: Template,
    },
    /// A functional unit.
    FunctionalUnit {
        /// Object name.
        name: Template,
        /// Containing execute stage (optional here; may instead come from an
        /// explicit `[[contains]]` edge).
        container: Option<Template>,
        /// Execution latency (latency-language string after `${}`).
        latency: Template,
        /// Operations the unit processes.
        ops: Vec<Spanned<String>>,
    },
    /// A register file.
    RegisterFile {
        /// Object name.
        name: Template,
        /// Register-name prefix (registers are `<prefix><i>`).
        prefix: Template,
        /// Register count.
        count: Spanned<PExpr>,
    },
    /// A data memory.
    Memory {
        /// Object name.
        name: Template,
        /// Read-transaction latency.
        read_latency: Template,
        /// Write-transaction latency.
        write_latency: Template,
        /// Words per transaction.
        port_width: Spanned<PExpr>,
        /// Simultaneous transactions.
        max_concurrent: Spanned<PExpr>,
        /// Claimed address-range base.
        base: Spanned<PExpr>,
        /// Claimed address-range size in words.
        words: Spanned<PExpr>,
    },
    /// `[[forward]]` routing edge.
    Forward {
        /// Source stage.
        from: Template,
        /// Target stage.
        to: Template,
    },
    /// `[[contains]]` containment edge.
    Contains {
        /// The containing execute stage.
        parent: Template,
        /// The contained functional unit.
        child: Template,
    },
    /// `[[reads]]` FU → register-file association.
    Reads {
        /// The functional unit.
        fu: Template,
        /// The register file it reads.
        rf: Template,
    },
    /// `[[writes]]` FU → register-file association.
    Writes {
        /// The functional unit.
        fu: Template,
        /// The register file it writes.
        rf: Template,
    },
    /// `[[mem_read]]` FU → memory association.
    MemRead {
        /// The functional unit.
        fu: Template,
        /// The memory it reads.
        mem: Template,
    },
    /// `[[mem_write]]` FU → memory association.
    MemWrite {
        /// The functional unit.
        fu: Template,
        /// The memory it writes.
        mem: Template,
    },
}

impl DeclBody {
    /// The `[[section]]` name of this declaration kind.
    pub fn section(&self) -> &'static str {
        match self {
            DeclBody::Stage { .. } => "stage",
            DeclBody::ExecuteStage { .. } => "execute_stage",
            DeclBody::FunctionalUnit { .. } => "functional_unit",
            DeclBody::RegisterFile { .. } => "register_file",
            DeclBody::Memory { .. } => "memory",
            DeclBody::Forward { .. } => "forward",
            DeclBody::Contains { .. } => "contains",
            DeclBody::Reads { .. } => "reads",
            DeclBody::Writes { .. } => "writes",
            DeclBody::MemRead { .. } => "mem_read",
            DeclBody::MemWrite { .. } => "mem_write",
        }
    }
}

/// One `name = value` parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    /// Parameter name.
    pub name: Spanned<String>,
    /// Integer value.
    pub value: Spanned<i64>,
}

/// One value item of a sweep dimension: a scalar expression or a half-open
/// range with an optional step (`lo..hi step s`; step defaults to 1).
#[derive(Debug, Clone, PartialEq)]
pub enum SweepItem {
    /// A single value.
    Scalar(PExpr),
    /// `lo..hi [step s]` — the half-open range `lo, lo+s, ...` below `hi`
    /// (mirrors `foreach`'s half-open ranges).
    Range {
        /// Lower bound (inclusive).
        lo: PExpr,
        /// Upper bound (exclusive).
        hi: PExpr,
        /// Stride (`None` = 1).
        step: Option<PExpr>,
    },
}

/// One `[sweep]` dimension: a `[params]` entry swept over a value list.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepDim {
    /// The swept parameter (must be declared in `[params]`).
    pub name: Spanned<String>,
    /// Value items, concatenated left to right.
    pub items: Vec<SweepItem>,
    /// Span of the dimension's value.
    pub span: Span,
}

/// The `[sweep]` section: a design-space declaration over the description's
/// own `[params]`. Purely declarative — compiling the description ignores
/// it; the DSE subsystem ([`crate::dse`]) enumerates it into candidate
/// architectures.
#[derive(Debug, Clone, PartialEq)]
pub struct Sweep {
    /// Swept dimensions in declaration order (enumeration is row-major:
    /// the last dimension varies fastest).
    pub dims: Vec<SweepDim>,
    /// Guard over swept names + base params; combinations evaluating to 0
    /// are excluded from the space (reserved key `when`).
    pub when: Option<Spanned<PExpr>>,
    /// Combinatorial blow-up cap override (reserved key `cap`).
    pub cap: Option<Spanned<i64>>,
    /// Span of the `[sweep]` header.
    pub span: Span,
}

/// A parsed architecture description (template form; see
/// [`crate::acadl::text::compile::expand`] for the flattened form).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Description {
    /// Architecture name template (`[arch] name = "..."`).
    pub name: Option<Template>,
    /// `[params]` in declaration order.
    pub params: Vec<Param>,
    /// `[isa] ops = [...]`: the declared instruction set. `None` when the
    /// section is absent (op checking is then skipped).
    pub isa: Option<Vec<Spanned<String>>>,
    /// `[fetch]` front-end.
    pub fetch: Option<Fetch>,
    /// `[mapper] family = "..."`.
    pub mapper: Option<Spanned<String>>,
    /// `[sweep]` design-space declaration (ignored by compilation; consumed
    /// by [`crate::dse`]).
    pub sweep: Option<Sweep>,
    /// Object and edge declarations in file order.
    pub decls: Vec<Decl>,
}

impl Description {
    /// Canonical TOML pretty-printer. The output reparses to an AST equal to
    /// `self` (spans excepted — they compare vacuously).
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        if let Some(name) = &self.name {
            let _ = writeln!(out, "[arch]");
            let _ = writeln!(out, "name = {}", quote(&name.source()));
            out.push('\n');
        }
        if !self.params.is_empty() {
            let _ = writeln!(out, "[params]");
            for p in &self.params {
                let _ = writeln!(out, "{} = {}", p.name.node, p.value.node);
            }
            out.push('\n');
        }
        if let Some(isa) = &self.isa {
            let _ = writeln!(out, "[isa]");
            let _ = writeln!(out, "ops = {}", quote_list(isa));
            out.push('\n');
        }
        if let Some(f) = &self.fetch {
            let _ = writeln!(out, "[fetch]");
            let _ = writeln!(out, "imem = {}", quote(&f.imem.source()));
            let _ = writeln!(out, "imem_read_latency = {}", pexpr_value(&f.imem_read_latency.node));
            let _ = writeln!(out, "imem_port_width = {}", pexpr_value(&f.imem_port_width.node));
            let _ = writeln!(out, "ifs = {}", quote(&f.ifs.source()));
            let _ = writeln!(out, "ifs_latency = {}", pexpr_value(&f.ifs_latency.node));
            let _ = writeln!(out, "issue_buffer = {}", pexpr_value(&f.issue_buffer.node));
            out.push('\n');
        }
        if let Some(m) = &self.mapper {
            let _ = writeln!(out, "[mapper]");
            let _ = writeln!(out, "family = {}", quote(&m.node));
            out.push('\n');
        }
        if let Some(s) = &self.sweep {
            let _ = writeln!(out, "[sweep]");
            for d in &s.dims {
                let _ = writeln!(out, "{} = {}", d.name.node, sweep_items_value(&d.items));
            }
            if let Some(w) = &s.when {
                let _ = writeln!(out, "when = {}", quote(&w.node.to_string()));
            }
            if let Some(c) = &s.cap {
                let _ = writeln!(out, "cap = {}", c.node);
            }
            out.push('\n');
        }
        for d in &self.decls {
            let _ = writeln!(out, "[[{}]]", d.body.section());
            match &d.body {
                DeclBody::Stage { name, latency } => {
                    let _ = writeln!(out, "name = {}", quote(&name.source()));
                    let _ = writeln!(out, "latency = {}", quote(&latency.source()));
                }
                DeclBody::ExecuteStage { name } => {
                    let _ = writeln!(out, "name = {}", quote(&name.source()));
                }
                DeclBody::FunctionalUnit { name, container, latency, ops } => {
                    let _ = writeln!(out, "name = {}", quote(&name.source()));
                    if let Some(c) = container {
                        let _ = writeln!(out, "in = {}", quote(&c.source()));
                    }
                    let _ = writeln!(out, "latency = {}", quote(&latency.source()));
                    let _ = writeln!(out, "ops = {}", quote_list(ops));
                }
                DeclBody::RegisterFile { name, prefix, count } => {
                    let _ = writeln!(out, "name = {}", quote(&name.source()));
                    let _ = writeln!(out, "prefix = {}", quote(&prefix.source()));
                    let _ = writeln!(out, "count = {}", pexpr_value(&count.node));
                }
                DeclBody::Memory {
                    name,
                    read_latency,
                    write_latency,
                    port_width,
                    max_concurrent,
                    base,
                    words,
                } => {
                    let _ = writeln!(out, "name = {}", quote(&name.source()));
                    let _ = writeln!(out, "read_latency = {}", quote(&read_latency.source()));
                    let _ = writeln!(out, "write_latency = {}", quote(&write_latency.source()));
                    let _ = writeln!(out, "port_width = {}", pexpr_value(&port_width.node));
                    let _ = writeln!(out, "max_concurrent = {}", pexpr_value(&max_concurrent.node));
                    let _ = writeln!(out, "base = {}", pexpr_value(&base.node));
                    let _ = writeln!(out, "words = {}", pexpr_value(&words.node));
                }
                DeclBody::Forward { from, to } => {
                    let _ = writeln!(out, "from = {}", quote(&from.source()));
                    let _ = writeln!(out, "to = {}", quote(&to.source()));
                }
                DeclBody::Contains { parent, child } => {
                    let _ = writeln!(out, "parent = {}", quote(&parent.source()));
                    let _ = writeln!(out, "child = {}", quote(&child.source()));
                }
                DeclBody::Reads { fu, rf } | DeclBody::Writes { fu, rf } => {
                    let _ = writeln!(out, "fu = {}", quote(&fu.source()));
                    let _ = writeln!(out, "rf = {}", quote(&rf.source()));
                }
                DeclBody::MemRead { fu, mem } | DeclBody::MemWrite { fu, mem } => {
                    let _ = writeln!(out, "fu = {}", quote(&fu.source()));
                    let _ = writeln!(out, "mem = {}", quote(&mem.source()));
                }
            }
            if !d.foreach.is_empty() {
                let ranges: Vec<String> = d
                    .foreach
                    .iter()
                    .map(|r| format!("{} in {}..{}", r.var.node, r.lo.node, r.hi.node))
                    .collect();
                let _ = writeln!(out, "foreach = {}", quote(&ranges.join(", ")));
            }
            if let Some(w) = &d.when {
                let _ = writeln!(out, "when = {}", quote(&w.node.to_string()));
            }
            out.push('\n');
        }
        out
    }
}

/// Print a `PExpr` as a TOML value: bare integer for constants, quoted
/// expression string otherwise.
fn pexpr_value(e: &PExpr) -> String {
    match e {
        PExpr::Const(v) => v.to_string(),
        other => quote(&other.to_string()),
    }
}

impl SweepItem {
    /// Canonical source form of one item (`4`, `rows * 2`, `2..17 step 2`).
    pub fn source(&self) -> String {
        match self {
            SweepItem::Scalar(e) => e.to_string(),
            SweepItem::Range { lo, hi, step: None } => format!("{lo}..{hi}"),
            SweepItem::Range { lo, hi, step: Some(s) } => format!("{lo}..{hi} step {s}"),
        }
    }
}

/// Print a sweep dimension's items as a TOML value: bare integer for a
/// single constant scalar, quoted item list otherwise. Reparsing the output
/// yields a structurally identical item list.
fn sweep_items_value(items: &[SweepItem]) -> String {
    if let [SweepItem::Scalar(PExpr::Const(v))] = items {
        return v.to_string();
    }
    let list: Vec<String> = items.iter().map(SweepItem::source).collect();
    quote(&list.join(", "))
}

/// Collect every variable name referenced by `e` into `out` (duplicates
/// included; callers dedupe as needed). Used for name-resolution checks on
/// expressions that cannot be evaluated yet (e.g. sweep guards, which bind
/// per-candidate values).
pub fn collect_vars(e: &PExpr, out: &mut Vec<String>) {
    match e {
        PExpr::Const(_) => {}
        PExpr::Var(name) => out.push(name.clone()),
        PExpr::Neg(a) => collect_vars(a, out),
        PExpr::Bin(_, a, b) | PExpr::Call(_, a, b) => {
            collect_vars(a, out);
            collect_vars(b, out);
        }
    }
}

fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            _ => out.push(c),
        }
    }
    out.push('"');
    out
}

fn quote_list(items: &[Spanned<String>]) -> String {
    let quoted: Vec<String> = items.iter().map(|s| quote(&s.node)).collect();
    format!("[{}]", quoted.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lookup_none(_: &str) -> Option<i64> {
        None
    }

    #[test]
    fn pexpr_eval_arithmetic_and_compare() {
        let vars = |name: &str| match name {
            "r" => Some(3i64),
            "c" => Some(5),
            _ => None,
        };
        let e = PExpr::Bin(
            BinOp::Add,
            Box::new(PExpr::Var("r".into())),
            Box::new(PExpr::Bin(
                BinOp::Mul,
                Box::new(PExpr::Const(2)),
                Box::new(PExpr::Var("c".into())),
            )),
        );
        assert_eq!(e.eval(&vars).unwrap(), 13);
        let cmp = PExpr::Bin(
            BinOp::Eq,
            Box::new(PExpr::Bin(
                BinOp::Rem,
                Box::new(PExpr::Var("r".into())),
                Box::new(PExpr::Const(2)),
            )),
            Box::new(PExpr::Const(1)),
        );
        assert_eq!(cmp.eval(&vars).unwrap(), 1);
    }

    #[test]
    fn pexpr_division_by_zero_errors() {
        let e = PExpr::Bin(
            BinOp::Div,
            Box::new(PExpr::Const(4)),
            Box::new(PExpr::Const(0)),
        );
        assert!(e.eval(&lookup_none).is_err());
        let e = PExpr::Call(
            Func::Cdiv,
            Box::new(PExpr::Const(4)),
            Box::new(PExpr::Const(0)),
        );
        assert!(e.eval(&lookup_none).is_err());
    }

    #[test]
    fn pexpr_unknown_var_errors() {
        assert!(PExpr::Var("nope".into()).eval(&lookup_none).is_err());
    }

    #[test]
    fn template_renders_holes() {
        let t = Template {
            segments: vec![
                Segment::Lit("pe[".into()),
                Segment::Expr(PExpr::Var("r".into())),
                Segment::Lit("][".into()),
                Segment::Expr(PExpr::Bin(
                    BinOp::Add,
                    Box::new(PExpr::Var("c".into())),
                    Box::new(PExpr::Const(1)),
                )),
                Segment::Lit("]".into()),
            ],
            span: Span::default(),
        };
        let vars = |name: &str| match name {
            "r" => Some(2i64),
            "c" => Some(0),
            _ => None,
        };
        assert_eq!(t.render(&vars).unwrap(), "pe[2][1]");
        assert_eq!(t.source(), "pe[${r}][${c + 1}]");
    }

    #[test]
    fn spans_compare_vacuously() {
        assert_eq!(Span::new(1, 2), Span::new(9, 9));
        assert_eq!(Spanned::new(5, Span::new(1, 1)), Spanned::bare(5));
    }
}
