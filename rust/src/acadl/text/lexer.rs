//! Line-oriented tokenizer for the TOML-flavored description format.
//!
//! Produces a flat token stream with 1-based line/column [`Span`]s. The
//! subset of TOML covered: `[section]` / `[[array-section]]` headers, bare
//! keys, `=`, integers, double-quoted strings (escapes: `\"` and `\\`),
//! single-line arrays, `#` comments, and significant newlines (one
//! key/value or header per line).

use super::ast::Span;
use super::Diagnostic;

/// One token with its source span.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    /// Token kind.
    pub kind: TokenKind,
    /// Source span.
    pub span: Span,
}

#[derive(Debug, Clone, PartialEq)]
/// Token kinds of the TOML-flavored format.
pub enum TokenKind {
    /// `[`
    LBracket,
    /// `]`
    RBracket,
    /// `=`
    Equals,
    /// `,`
    Comma,
    /// Bare key / identifier (letters, digits, `_`).
    Ident(String),
    /// Integer literal (sign handled by the parser where legal).
    Int(i64),
    /// Double-quoted string contents (unescaped).
    Str(String),
    /// End of line (collapsed; comments and blank lines produce one).
    Newline,
}

impl TokenKind {
    /// Human-readable token description for diagnostics.
    pub fn describe(&self) -> String {
        match self {
            TokenKind::LBracket => "`[`".into(),
            TokenKind::RBracket => "`]`".into(),
            TokenKind::Equals => "`=`".into(),
            TokenKind::Comma => "`,`".into(),
            TokenKind::Ident(s) => format!("identifier `{s}`"),
            TokenKind::Int(v) => format!("integer `{v}`"),
            TokenKind::Str(_) => "string".into(),
            TokenKind::Newline => "end of line".into(),
        }
    }
}

/// Tokenize `src`. Errors (with spans) are returned as diagnostics; the
/// token stream is best-effort up to the first error.
pub fn lex(src: &str) -> Result<Vec<Token>, Diagnostic> {
    let mut toks = Vec::new();
    for (line_idx, line) in src.lines().enumerate() {
        let line_no = line_idx as u32 + 1;
        lex_line(line, line_no, &mut toks)?;
        // collapse: only emit a newline if the line produced tokens
        if toks.last().map(|t| t.kind != TokenKind::Newline).unwrap_or(false) {
            toks.push(Token {
                kind: TokenKind::Newline,
                span: Span::new(line_no, line.chars().count() as u32 + 1),
            });
        }
    }
    Ok(toks)
}

fn lex_line(line: &str, line_no: u32, toks: &mut Vec<Token>) -> Result<(), Diagnostic> {
    let chars: Vec<char> = line.chars().collect();
    let mut i = 0usize;
    while i < chars.len() {
        let col = i as u32 + 1;
        let span = Span::new(line_no, col);
        match chars[i] {
            ' ' | '\t' => i += 1,
            '#' => break, // comment to end of line
            '[' => {
                toks.push(Token { kind: TokenKind::LBracket, span });
                i += 1;
            }
            ']' => {
                toks.push(Token { kind: TokenKind::RBracket, span });
                i += 1;
            }
            '=' => {
                toks.push(Token { kind: TokenKind::Equals, span });
                i += 1;
            }
            ',' => {
                toks.push(Token { kind: TokenKind::Comma, span });
                i += 1;
            }
            '"' => {
                let mut s = String::new();
                i += 1;
                loop {
                    match chars.get(i) {
                        None => {
                            return Err(Diagnostic::error(span, "unterminated string"));
                        }
                        Some('"') => {
                            i += 1;
                            break;
                        }
                        Some('\\') => match chars.get(i + 1) {
                            Some('"') => {
                                s.push('"');
                                i += 2;
                            }
                            Some('\\') => {
                                s.push('\\');
                                i += 2;
                            }
                            other => {
                                return Err(Diagnostic::error(
                                    Span::new(line_no, i as u32 + 2),
                                    format!(
                                        "unsupported escape `\\{}` (only \\\" and \\\\)",
                                        other.map(|c| c.to_string()).unwrap_or_default()
                                    ),
                                ));
                            }
                        },
                        Some(&c) => {
                            s.push(c);
                            i += 1;
                        }
                    }
                }
                toks.push(Token { kind: TokenKind::Str(s), span });
            }
            c if c.is_ascii_digit()
                || (c == '-' && chars.get(i + 1).is_some_and(|n| n.is_ascii_digit())) =>
            {
                let start = i;
                i += 1; // sign or first digit
                while i < chars.len() && chars[i].is_ascii_digit() {
                    i += 1;
                }
                let text: String = chars[start..i].iter().collect();
                let v: i64 = text.parse().map_err(|_| {
                    Diagnostic::error(span, format!("integer `{text}` out of range"))
                })?;
                toks.push(Token { kind: TokenKind::Int(v), span });
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < chars.len() && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                let text: String = chars[start..i].iter().collect();
                toks.push(Token { kind: TokenKind::Ident(text), span });
            }
            c => {
                return Err(Diagnostic::error(span, format!("unexpected character `{c}`")));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        lex(src).unwrap().into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn lexes_headers_and_pairs() {
        let ks = kinds("[arch]\nname = \"x\"\n");
        assert_eq!(
            ks,
            vec![
                TokenKind::LBracket,
                TokenKind::Ident("arch".into()),
                TokenKind::RBracket,
                TokenKind::Newline,
                TokenKind::Ident("name".into()),
                TokenKind::Equals,
                TokenKind::Str("x".into()),
                TokenKind::Newline,
            ]
        );
    }

    #[test]
    fn lexes_arrays_comments_blank_lines() {
        let ks = kinds("# header comment\n\nops = [\"a\", \"b\"]  # trailing\n");
        assert_eq!(
            ks,
            vec![
                TokenKind::Ident("ops".into()),
                TokenKind::Equals,
                TokenKind::LBracket,
                TokenKind::Str("a".into()),
                TokenKind::Comma,
                TokenKind::Str("b".into()),
                TokenKind::RBracket,
                TokenKind::Newline,
            ]
        );
    }

    #[test]
    fn lexes_negative_ints_and_escapes() {
        let ks = kinds("x = -12\ny = \"a\\\"b\\\\c\"\n");
        assert!(ks.contains(&TokenKind::Int(-12)));
        assert!(ks.contains(&TokenKind::Str("a\"b\\c".into())));
    }

    #[test]
    fn spans_are_one_based() {
        let toks = lex("  key = 1").unwrap();
        assert_eq!(toks[0].span, Span::new(1, 3));
        // vacuous Eq on Span: check fields directly
        assert_eq!((toks[0].span.line, toks[0].span.col), (1, 3));
        assert_eq!((toks[2].span.line, toks[2].span.col), (1, 9));
    }

    #[test]
    fn rejects_bad_input() {
        assert!(lex("key = @").is_err());
        assert!(lex("s = \"unterminated").is_err());
        assert!(lex("s = \"bad \\n escape\"").is_err());
    }
}
