//! Parser: token stream → [`Description`] AST, plus the two string-level
//! sub-parsers — `${}`-interpolated [`Template`]s and [`PExpr`] parameter
//! expressions (with comparisons and `&&`/`||` for `when` guards).

use super::ast::{
    BinOp, Decl, DeclBody, Description, Fetch, ForRange, Func, PExpr, Param, Segment, Span,
    Spanned, Sweep, SweepDim, SweepItem, Template,
};
use super::lexer::{lex, Token, TokenKind};
use super::Diagnostic;

/// Parse a description source file.
pub fn parse(src: &str) -> Result<Description, Diagnostic> {
    let toks = lex(src)?;
    Parser { toks, pos: 0 }.description()
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

/// A raw `key = value` pair within one section.
#[derive(Debug, Clone)]
struct RawPair {
    key: String,
    key_span: Span,
    value: Val,
}

#[derive(Debug, Clone)]
enum Val {
    Int(i64, Span),
    Str(String, Span),
    List(Vec<(String, Span)>, Span),
}

impl Val {
    fn span(&self) -> Span {
        match self {
            Val::Int(_, s) | Val::Str(_, s) | Val::List(_, s) => *s,
        }
    }
}

impl Parser {
    fn peek(&self) -> Option<&Token> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Option<Token> {
        let t = self.toks.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn here(&self) -> Span {
        self.peek().map(|t| t.span).unwrap_or_else(|| {
            self.toks.last().map(|t| t.span).unwrap_or_default()
        })
    }

    fn skip_newlines(&mut self) {
        while matches!(self.peek().map(|t| &t.kind), Some(TokenKind::Newline)) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, kind: &TokenKind, what: &str) -> Result<Span, Diagnostic> {
        match self.next() {
            Some(t) if t.kind == *kind => Ok(t.span),
            Some(t) => Err(Diagnostic::error(
                t.span,
                format!("expected {what}, found {}", t.kind.describe()),
            )),
            None => Err(Diagnostic::error(self.here(), format!("expected {what}, found end of file"))),
        }
    }

    /// `[name]` or `[[name]]` header; returns (name, is_array, span).
    fn header(&mut self) -> Result<(String, bool, Span), Diagnostic> {
        let span = self.expect(&TokenKind::LBracket, "`[`")?;
        let is_array = matches!(self.peek().map(|t| &t.kind), Some(TokenKind::LBracket));
        if is_array {
            self.pos += 1;
        }
        let name = match self.next() {
            Some(Token { kind: TokenKind::Ident(n), .. }) => n,
            Some(t) => {
                return Err(Diagnostic::error(
                    t.span,
                    format!("expected section name, found {}", t.kind.describe()),
                ))
            }
            None => return Err(Diagnostic::error(span, "expected section name")),
        };
        self.expect(&TokenKind::RBracket, "`]`")?;
        if is_array {
            self.expect(&TokenKind::RBracket, "`]]`")?;
        }
        self.expect(&TokenKind::Newline, "end of line after section header")?;
        Ok((name, is_array, span))
    }

    /// Key-value pairs up to the next section header or end of file.
    fn pairs(&mut self) -> Result<Vec<RawPair>, Diagnostic> {
        let mut out = Vec::new();
        loop {
            self.skip_newlines();
            match self.peek().map(|t| &t.kind) {
                None | Some(TokenKind::LBracket) => return Ok(out),
                Some(TokenKind::Ident(_)) => {}
                Some(k) => {
                    let span = self.here();
                    return Err(Diagnostic::error(
                        span,
                        format!("expected `key = value`, found {}", k.describe()),
                    ));
                }
            }
            let (key, key_span) = match self.next() {
                Some(Token { kind: TokenKind::Ident(k), span }) => (k, span),
                _ => unreachable!("peeked an identifier"),
            };
            self.expect(&TokenKind::Equals, "`=`")?;
            let value = self.value()?;
            self.expect(&TokenKind::Newline, "end of line after value")?;
            out.push(RawPair { key, key_span, value });
        }
    }

    fn value(&mut self) -> Result<Val, Diagnostic> {
        match self.next() {
            Some(Token { kind: TokenKind::Int(v), span }) => Ok(Val::Int(v, span)),
            Some(Token { kind: TokenKind::Str(s), span }) => Ok(Val::Str(s, span)),
            Some(Token { kind: TokenKind::LBracket, span }) => {
                let mut items = Vec::new();
                loop {
                    match self.next() {
                        Some(Token { kind: TokenKind::RBracket, .. }) => break,
                        Some(Token { kind: TokenKind::Str(s), span }) => {
                            items.push((s, span));
                            match self.peek().map(|t| &t.kind) {
                                Some(TokenKind::Comma) => {
                                    self.pos += 1;
                                }
                                Some(TokenKind::RBracket) => {}
                                _ => {
                                    let at = self.here();
                                    return Err(Diagnostic::error(
                                        at,
                                        "expected `,` or `]` in array",
                                    ));
                                }
                            }
                        }
                        Some(t) => {
                            return Err(Diagnostic::error(
                                t.span,
                                format!("expected string in array, found {}", t.kind.describe()),
                            ))
                        }
                        None => return Err(Diagnostic::error(span, "unterminated array")),
                    }
                }
                Ok(Val::List(items, span))
            }
            Some(t) => Err(Diagnostic::error(
                t.span,
                format!("expected a value, found {}", t.kind.describe()),
            )),
            None => Err(Diagnostic::error(self.here(), "expected a value, found end of file")),
        }
    }

    fn description(&mut self) -> Result<Description, Diagnostic> {
        let mut desc = Description::default();
        // an *empty* first [params] section must still make a second one a
        // duplicate (the other singletons fail fast on missing keys)
        let mut seen_params = false;
        loop {
            self.skip_newlines();
            if self.peek().is_none() {
                return Ok(desc);
            }
            let (section, is_array, span) = self.header()?;
            let pairs = self.pairs()?;
            // singleton sections may appear at most once (last-wins would
            // silently discard the earlier one)
            if !is_array {
                let already = match section.as_str() {
                    "arch" => desc.name.is_some(),
                    "params" => std::mem::replace(&mut seen_params, true),
                    "isa" => desc.isa.is_some(),
                    "fetch" => desc.fetch.is_some(),
                    "mapper" => desc.mapper.is_some(),
                    "sweep" => desc.sweep.is_some(),
                    _ => false,
                };
                if already {
                    return Err(Diagnostic::error(
                        span,
                        format!("duplicate section [{section}]"),
                    ));
                }
            }
            match (section.as_str(), is_array) {
                ("arch", false) => {
                    let mut p = PairSet::new(pairs, span, "arch")?;
                    desc.name = Some(p.template("name")?);
                    p.finish()?;
                }
                ("params", false) => {
                    for pair in pairs {
                        match pair.value {
                            Val::Int(v, vspan) => desc.params.push(Param {
                                name: Spanned::new(pair.key, pair.key_span),
                                value: Spanned::new(v, vspan),
                            }),
                            other => {
                                return Err(Diagnostic::error(
                                    other.span(),
                                    "parameters must be integers",
                                ))
                            }
                        }
                    }
                }
                ("isa", false) => {
                    let mut p = PairSet::new(pairs, span, "isa")?;
                    desc.isa = Some(p.str_list("ops")?);
                    p.finish()?;
                }
                ("fetch", false) => {
                    let mut p = PairSet::new(pairs, span, "fetch")?;
                    desc.fetch = Some(Fetch {
                        imem: p.template("imem")?,
                        imem_read_latency: p.pexpr("imem_read_latency")?,
                        imem_port_width: p.pexpr("imem_port_width")?,
                        ifs: p.template("ifs")?,
                        ifs_latency: p.pexpr("ifs_latency")?,
                        issue_buffer: p.pexpr("issue_buffer")?,
                        span,
                    });
                    p.finish()?;
                }
                ("mapper", false) => {
                    let mut p = PairSet::new(pairs, span, "mapper")?;
                    desc.mapper = Some(p.string("family")?);
                    p.finish()?;
                }
                ("sweep", false) => {
                    desc.sweep = Some(Self::sweep(pairs, span)?);
                }
                (name, true) => {
                    desc.decls.push(self.decl(name, span, pairs)?);
                }
                (other, false) => {
                    return Err(Diagnostic::error(
                        span,
                        format!(
                            "unknown section `[{other}]` (arch|params|isa|fetch|mapper, or a \
                             `[[...]]` declaration)"
                        ),
                    ))
                }
            }
        }
    }

    fn decl(&mut self, section: &str, span: Span, pairs: Vec<RawPair>) -> Result<Decl, Diagnostic> {
        let mut p = PairSet::new(pairs, span, section)?;
        let body = match section {
            "stage" => DeclBody::Stage { name: p.template("name")?, latency: p.template("latency")? },
            "execute_stage" => DeclBody::ExecuteStage { name: p.template("name")? },
            "functional_unit" => DeclBody::FunctionalUnit {
                name: p.template("name")?,
                container: p.template_opt("in")?,
                latency: p.template("latency")?,
                ops: p.str_list("ops")?,
            },
            "register_file" => DeclBody::RegisterFile {
                name: p.template("name")?,
                prefix: p.template("prefix")?,
                count: p.pexpr("count")?,
            },
            "memory" => DeclBody::Memory {
                name: p.template("name")?,
                read_latency: p.template("read_latency")?,
                write_latency: p.template("write_latency")?,
                port_width: p.pexpr("port_width")?,
                max_concurrent: p.pexpr("max_concurrent")?,
                base: p.pexpr("base")?,
                words: p.pexpr("words")?,
            },
            "forward" => DeclBody::Forward { from: p.template("from")?, to: p.template("to")? },
            "contains" => {
                DeclBody::Contains { parent: p.template("parent")?, child: p.template("child")? }
            }
            "reads" => DeclBody::Reads { fu: p.template("fu")?, rf: p.template("rf")? },
            "writes" => DeclBody::Writes { fu: p.template("fu")?, rf: p.template("rf")? },
            "mem_read" => DeclBody::MemRead { fu: p.template("fu")?, mem: p.template("mem")? },
            "mem_write" => DeclBody::MemWrite { fu: p.template("fu")?, mem: p.template("mem")? },
            other => {
                return Err(Diagnostic::error(
                    span,
                    format!(
                        "unknown declaration `[[{other}]]` (stage|execute_stage|functional_unit|\
                         register_file|memory|forward|contains|reads|writes|mem_read|mem_write)"
                    ),
                ))
            }
        };
        let foreach = match p.take("foreach") {
            Some(pair) => match pair.value {
                Val::Str(s, vspan) => parse_foreach(&s, vspan)?,
                other => return Err(Diagnostic::error(other.span(), "foreach must be a string")),
            },
            None => Vec::new(),
        };
        let when = match p.take("when") {
            Some(pair) => match pair.value {
                Val::Str(s, vspan) => Some(Spanned::new(parse_pexpr(&s, vspan)?, vspan)),
                other => return Err(Diagnostic::error(other.span(), "when must be a string")),
            },
            None => None,
        };
        p.finish()?;
        Ok(Decl { body, foreach, when, span })
    }

    /// Parse the `[sweep]` section body. Every key except the reserved
    /// `when` (guard) and `cap` (blow-up bound) declares one swept
    /// dimension, in file order.
    fn sweep(pairs: Vec<RawPair>, span: Span) -> Result<Sweep, Diagnostic> {
        for (i, a) in pairs.iter().enumerate() {
            if pairs[..i].iter().any(|b| b.key == a.key) {
                return Err(Diagnostic::error(
                    a.key_span,
                    format!("duplicate key `{}` in [sweep]", a.key),
                ));
            }
        }
        let mut sweep = Sweep { dims: Vec::new(), when: None, cap: None, span };
        for pair in pairs {
            let RawPair { key, key_span, value } = pair;
            if key == "when" {
                match value {
                    Val::Str(s, vspan) => {
                        sweep.when = Some(Spanned::new(parse_pexpr(&s, vspan)?, vspan));
                    }
                    other => {
                        return Err(Diagnostic::error(
                            other.span(),
                            "sweep `when` must be a string",
                        ))
                    }
                }
            } else if key == "cap" {
                match value {
                    Val::Int(v, vspan) => sweep.cap = Some(Spanned::new(v, vspan)),
                    other => {
                        return Err(Diagnostic::error(
                            other.span(),
                            "sweep `cap` must be an integer",
                        ))
                    }
                }
            } else {
                match value {
                    Val::Int(v, vspan) => sweep.dims.push(SweepDim {
                        name: Spanned::new(key, key_span),
                        items: vec![SweepItem::Scalar(PExpr::Const(v))],
                        span: vspan,
                    }),
                    Val::Str(s, vspan) => sweep.dims.push(SweepDim {
                        name: Spanned::new(key, key_span),
                        items: parse_sweep_items(&s, vspan)?,
                        span: vspan,
                    }),
                    other => {
                        return Err(Diagnostic::error(
                            other.span(),
                            format!(
                                "sweep dimension `{key}` must be an integer or a value-list \
                                 string"
                            ),
                        ))
                    }
                }
            }
        }
        Ok(sweep)
    }
}

/// Split `src` at top-level (paren-depth-zero) occurrences of `sep`.
fn split_top_level<'a>(src: &'a str, sep: &str) -> Vec<&'a str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let bytes = src.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'(' => depth += 1,
            b')' => depth = depth.saturating_sub(1),
            // byte-wise compare: separators are ASCII, so a match position
            // is always a char boundary even in non-ASCII input
            _ if depth == 0 && bytes[i..].starts_with(sep.as_bytes()) => {
                parts.push(&src[start..i]);
                i += sep.len();
                start = i;
                continue;
            }
            _ => {}
        }
        i += 1;
    }
    parts.push(&src[start..]);
    parts
}

/// Parse a sweep dimension's value list: comma-separated items, each a
/// scalar expression or a `lo..hi [step s]` half-open range. Commas inside
/// function calls do not separate items.
pub fn parse_sweep_items(src: &str, span: Span) -> Result<Vec<SweepItem>, Diagnostic> {
    let mut items = Vec::new();
    for raw in split_top_level(src, ",") {
        let raw = raw.trim();
        if raw.is_empty() {
            continue;
        }
        let range_parts = split_top_level(raw, "..");
        match range_parts.as_slice() {
            [single] => items.push(SweepItem::Scalar(parse_pexpr(single, span)?)),
            [lo, hi] => {
                let (hi, step) = match hi.find(" step ") {
                    Some(at) => (
                        &hi[..at],
                        Some(parse_pexpr(&hi[at + " step ".len()..], span)?),
                    ),
                    None => (*hi, None),
                };
                items.push(SweepItem::Range {
                    lo: parse_pexpr(lo, span)?,
                    hi: parse_pexpr(hi, span)?,
                    step,
                });
            }
            _ => {
                return Err(Diagnostic::error(
                    span,
                    format!("sweep item {raw:?} has more than one `..`"),
                ))
            }
        }
    }
    if items.is_empty() {
        return Err(Diagnostic::error(span, "empty sweep value list"));
    }
    Ok(items)
}

/// Typed accessor over one section's raw pairs, with duplicate/unknown-key
/// detection.
struct PairSet {
    pairs: Vec<Option<RawPair>>,
    section_span: Span,
    section: String,
}

impl PairSet {
    fn new(pairs: Vec<RawPair>, section_span: Span, section: &str) -> Result<Self, Diagnostic> {
        for (i, a) in pairs.iter().enumerate() {
            if pairs[..i].iter().any(|b| b.key == a.key) {
                return Err(Diagnostic::error(
                    a.key_span,
                    format!("duplicate key `{}` in [{section}]", a.key),
                ));
            }
        }
        Ok(Self { pairs: pairs.into_iter().map(Some).collect(), section_span, section: section.into() })
    }

    fn take(&mut self, key: &str) -> Option<RawPair> {
        self.pairs
            .iter_mut()
            .find(|p| p.as_ref().is_some_and(|p| p.key == key))
            .and_then(Option::take)
    }

    fn required(&mut self, key: &str) -> Result<RawPair, Diagnostic> {
        self.take(key).ok_or_else(|| {
            Diagnostic::error(
                self.section_span,
                format!("[{}] is missing required key `{key}`", self.section),
            )
        })
    }

    fn template(&mut self, key: &str) -> Result<Template, Diagnostic> {
        let pair = self.required(key)?;
        val_template(pair.value)
    }

    fn template_opt(&mut self, key: &str) -> Result<Option<Template>, Diagnostic> {
        match self.take(key) {
            Some(pair) => Ok(Some(val_template(pair.value)?)),
            None => Ok(None),
        }
    }

    fn pexpr(&mut self, key: &str) -> Result<Spanned<PExpr>, Diagnostic> {
        let pair = self.required(key)?;
        match pair.value {
            Val::Int(v, span) => Ok(Spanned::new(PExpr::Const(v), span)),
            Val::Str(s, span) => Ok(Spanned::new(parse_pexpr(&s, span)?, span)),
            Val::List(_, span) => {
                Err(Diagnostic::error(span, format!("`{key}` must be an integer or expression")))
            }
        }
    }

    fn string(&mut self, key: &str) -> Result<Spanned<String>, Diagnostic> {
        let pair = self.required(key)?;
        match pair.value {
            Val::Str(s, span) => Ok(Spanned::new(s, span)),
            other => Err(Diagnostic::error(other.span(), format!("`{key}` must be a string"))),
        }
    }

    fn str_list(&mut self, key: &str) -> Result<Vec<Spanned<String>>, Diagnostic> {
        let pair = self.required(key)?;
        match pair.value {
            Val::List(items, _) => {
                Ok(items.into_iter().map(|(s, span)| Spanned::new(s, span)).collect())
            }
            other => {
                Err(Diagnostic::error(other.span(), format!("`{key}` must be a string array")))
            }
        }
    }

    fn finish(self) -> Result<(), Diagnostic> {
        if let Some(extra) = self.pairs.into_iter().flatten().next() {
            return Err(Diagnostic::error(
                extra.key_span,
                format!("unknown key `{}` in [{}]", extra.key, self.section),
            ));
        }
        Ok(())
    }
}

fn val_template(val: Val) -> Result<Template, Diagnostic> {
    match val {
        Val::Str(s, span) => parse_template(&s, span),
        Val::Int(v, span) => {
            let mut t = Template::lit(v.to_string());
            t.span = span;
            Ok(t)
        }
        Val::List(_, span) => Err(Diagnostic::error(span, "expected a string, found array")),
    }
}

/// Parse a `${}`-interpolated template string.
pub fn parse_template(src: &str, span: Span) -> Result<Template, Diagnostic> {
    let mut segments = Vec::new();
    let mut lit = String::new();
    let mut rest = src;
    while let Some(start) = rest.find("${") {
        lit.push_str(&rest[..start]);
        let after = &rest[start + 2..];
        let end = after.find('}').ok_or_else(|| {
            Diagnostic::error(span, format!("unclosed `${{` in template {src:?}"))
        })?;
        if !lit.is_empty() {
            segments.push(Segment::Lit(std::mem::take(&mut lit)));
        }
        segments.push(Segment::Expr(parse_pexpr(&after[..end], span)?));
        rest = &after[end + 1..];
    }
    lit.push_str(rest);
    if !lit.is_empty() {
        segments.push(Segment::Lit(lit));
    }
    Ok(Template { segments, span })
}

/// Parse a `foreach` clause: `var in lo..hi, var2 in lo2..hi2, ...`.
pub fn parse_foreach(src: &str, span: Span) -> Result<Vec<ForRange>, Diagnostic> {
    let mut out = Vec::new();
    for clause in src.split(',') {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        let (var, range) = clause.split_once(" in ").ok_or_else(|| {
            Diagnostic::error(span, format!("foreach clause {clause:?} must be `var in lo..hi`"))
        })?;
        let var = var.trim();
        if var.is_empty() || !var.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            return Err(Diagnostic::error(span, format!("bad foreach variable {var:?}")));
        }
        let (lo, hi) = range.split_once("..").ok_or_else(|| {
            Diagnostic::error(span, format!("foreach range {range:?} must be `lo..hi`"))
        })?;
        out.push(ForRange {
            var: Spanned::new(var.to_string(), span),
            lo: Spanned::new(parse_pexpr(lo, span)?, span),
            hi: Spanned::new(parse_pexpr(hi, span)?, span),
        });
    }
    if out.is_empty() {
        return Err(Diagnostic::error(span, "empty foreach clause"));
    }
    Ok(out)
}

// ---- parameter expression sub-parser ---------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum PTok {
    Int(i64),
    Ident(String),
    Op(BinOp),
    Minus,
    LParen,
    RParen,
    Comma,
}

fn pexpr_lex(src: &str, span: Span) -> Result<Vec<PTok>, Diagnostic> {
    let bytes = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        match c {
            ' ' | '\t' => i += 1,
            '+' => {
                toks.push(PTok::Op(BinOp::Add));
                i += 1;
            }
            '-' => {
                toks.push(PTok::Minus);
                i += 1;
            }
            '*' => {
                toks.push(PTok::Op(BinOp::Mul));
                i += 1;
            }
            '/' => {
                toks.push(PTok::Op(BinOp::Div));
                i += 1;
            }
            '%' => {
                toks.push(PTok::Op(BinOp::Rem));
                i += 1;
            }
            '(' => {
                toks.push(PTok::LParen);
                i += 1;
            }
            ')' => {
                toks.push(PTok::RParen);
                i += 1;
            }
            ',' => {
                toks.push(PTok::Comma);
                i += 1;
            }
            '=' | '!' | '<' | '>' | '&' | '|' => {
                // get() is None when i+2 overruns or splits a multi-byte
                // char; both fall through to the single-char/error arms
                let two = src.get(i..i + 2).unwrap_or("");
                let (op, len) = match two {
                    "==" => (BinOp::Eq, 2),
                    "!=" => (BinOp::Ne, 2),
                    "<=" => (BinOp::Le, 2),
                    ">=" => (BinOp::Ge, 2),
                    "&&" => (BinOp::And, 2),
                    "||" => (BinOp::Or, 2),
                    _ if c == '<' => (BinOp::Lt, 1),
                    _ if c == '>' => (BinOp::Gt, 1),
                    _ => {
                        return Err(Diagnostic::error(
                            span,
                            format!("unexpected `{c}` in expression {src:?}"),
                        ))
                    }
                };
                toks.push(PTok::Op(op));
                i += len;
            }
            '0'..='9' => {
                let s = i;
                while i < bytes.len() && bytes[i].is_ascii_digit() {
                    i += 1;
                }
                let v = src[s..i].parse().map_err(|_| {
                    Diagnostic::error(span, format!("integer out of range in {src:?}"))
                })?;
                toks.push(PTok::Int(v));
            }
            'a'..='z' | 'A'..='Z' | '_' => {
                let s = i;
                while i < bytes.len() && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_') {
                    i += 1;
                }
                toks.push(PTok::Ident(src[s..i].to_string()));
            }
            _ => {
                return Err(Diagnostic::error(
                    span,
                    format!("unexpected character `{c}` in expression {src:?}"),
                ))
            }
        }
    }
    Ok(toks)
}

/// Parse a parameter expression string.
pub fn parse_pexpr(src: &str, span: Span) -> Result<PExpr, Diagnostic> {
    let toks = pexpr_lex(src, span)?;
    let mut p = PParser { toks, pos: 0, span, src: src.to_string() };
    let e = p.or_expr()?;
    if p.pos != p.toks.len() {
        return Err(Diagnostic::error(span, format!("trailing tokens in expression {src:?}")));
    }
    Ok(e)
}

struct PParser {
    toks: Vec<PTok>,
    pos: usize,
    span: Span,
    src: String,
}

impl PParser {
    fn err(&self, msg: &str) -> Diagnostic {
        Diagnostic::error(self.span, format!("{msg} in expression {:?}", self.src))
    }

    fn peek(&self) -> Option<&PTok> {
        self.toks.get(self.pos)
    }

    fn eat_op(&mut self, ops: &[BinOp]) -> Option<BinOp> {
        if let Some(PTok::Op(op)) = self.peek() {
            if ops.contains(op) {
                let op = *op;
                self.pos += 1;
                return Some(op);
            }
        }
        None
    }

    fn or_expr(&mut self) -> Result<PExpr, Diagnostic> {
        let mut lhs = self.and_expr()?;
        while self.eat_op(&[BinOp::Or]).is_some() {
            lhs = PExpr::Bin(BinOp::Or, Box::new(lhs), Box::new(self.and_expr()?));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<PExpr, Diagnostic> {
        let mut lhs = self.cmp_expr()?;
        while self.eat_op(&[BinOp::And]).is_some() {
            lhs = PExpr::Bin(BinOp::And, Box::new(lhs), Box::new(self.cmp_expr()?));
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<PExpr, Diagnostic> {
        let lhs = self.sum()?;
        if let Some(op) =
            self.eat_op(&[BinOp::Eq, BinOp::Ne, BinOp::Lt, BinOp::Le, BinOp::Gt, BinOp::Ge])
        {
            let rhs = self.sum()?;
            return Ok(PExpr::Bin(op, Box::new(lhs), Box::new(rhs)));
        }
        Ok(lhs)
    }

    fn sum(&mut self) -> Result<PExpr, Diagnostic> {
        let mut lhs = self.term()?;
        loop {
            if self.eat_op(&[BinOp::Add]).is_some() {
                lhs = PExpr::Bin(BinOp::Add, Box::new(lhs), Box::new(self.term()?));
            } else if matches!(self.peek(), Some(PTok::Minus)) {
                self.pos += 1;
                lhs = PExpr::Bin(BinOp::Sub, Box::new(lhs), Box::new(self.term()?));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn term(&mut self) -> Result<PExpr, Diagnostic> {
        let mut lhs = self.unary()?;
        while let Some(op) = self.eat_op(&[BinOp::Mul, BinOp::Div, BinOp::Rem]) {
            lhs = PExpr::Bin(op, Box::new(lhs), Box::new(self.unary()?));
        }
        Ok(lhs)
    }

    fn unary(&mut self) -> Result<PExpr, Diagnostic> {
        if matches!(self.peek(), Some(PTok::Minus)) {
            self.pos += 1;
            let inner = self.unary()?;
            // fold so `-3` round-trips as Const(-3), not Neg(Const(3))
            if let PExpr::Const(v) = inner {
                return Ok(PExpr::Const(v.wrapping_neg()));
            }
            return Ok(PExpr::Neg(Box::new(inner)));
        }
        self.atom()
    }

    fn atom(&mut self) -> Result<PExpr, Diagnostic> {
        match self.toks.get(self.pos).cloned() {
            Some(PTok::Int(v)) => {
                self.pos += 1;
                Ok(PExpr::Const(v))
            }
            Some(PTok::LParen) => {
                self.pos += 1;
                let e = self.or_expr()?;
                match self.toks.get(self.pos) {
                    Some(PTok::RParen) => {
                        self.pos += 1;
                        Ok(e)
                    }
                    _ => Err(self.err("expected `)`")),
                }
            }
            Some(PTok::Ident(name)) => {
                self.pos += 1;
                if matches!(self.peek(), Some(PTok::LParen)) {
                    let func = match name.as_str() {
                        "cdiv" => Func::Cdiv,
                        "max" => Func::Max,
                        "min" => Func::Min,
                        other => return Err(self.err(&format!("unknown function `{other}`"))),
                    };
                    self.pos += 1; // (
                    let a = self.or_expr()?;
                    if !matches!(self.toks.get(self.pos), Some(PTok::Comma)) {
                        return Err(self.err("expected `,`"));
                    }
                    self.pos += 1;
                    let b = self.or_expr()?;
                    if !matches!(self.toks.get(self.pos), Some(PTok::RParen)) {
                        return Err(self.err("expected `)`"));
                    }
                    self.pos += 1;
                    return Ok(PExpr::Call(func, Box::new(a), Box::new(b)));
                }
                Ok(PExpr::Var(name))
            }
            _ => Err(self.err("expected a value")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pe(src: &str) -> PExpr {
        parse_pexpr(src, Span::default()).unwrap()
    }

    #[test]
    fn pexpr_precedence_and_roundtrip() {
        for src in [
            "1 + 2 * 3",
            "(1 + 2) * 3",
            "rows + 2 * cols",
            "(r + c) % 2 == 1",
            "r > 0 && c < cols - 1",
            "cdiv(x, 8) * cdiv(y, 8) + max(a, b) - min(a, b)",
            "-x + -3",
            "a / b % c",
            "idx * 16777216",
        ] {
            let ast = pe(src);
            let printed = ast.to_string();
            let reparsed = pe(&printed);
            assert_eq!(ast, reparsed, "{src} -> {printed}");
        }
    }

    #[test]
    fn pexpr_negative_literal_folds() {
        assert_eq!(pe("-3"), PExpr::Const(-3));
        assert_eq!(pe("-3").to_string(), "-3");
    }

    #[test]
    fn pexpr_errors() {
        assert!(parse_pexpr("1 +", Span::default()).is_err());
        assert!(parse_pexpr("foo(1, 2)", Span::default()).is_err());
        assert!(parse_pexpr("(1", Span::default()).is_err());
        assert!(parse_pexpr("1 2", Span::default()).is_err());
        assert!(parse_pexpr("a ? b", Span::default()).is_err());
    }

    #[test]
    fn template_parses_holes() {
        let t = parse_template("pe[${r}][${c + 1}]", Span::default()).unwrap();
        assert_eq!(t.source(), "pe[${r}][${c + 1}]");
        assert_eq!(t.segments.len(), 5);
        assert!(parse_template("bad ${r", Span::default()).is_err());
    }

    #[test]
    fn foreach_parses_ranges() {
        let f = parse_foreach("r in 0..rows, c in 0..cols", Span::default()).unwrap();
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].var.node, "r");
        assert_eq!(f[1].hi.node, PExpr::Var("cols".into()));
        assert!(parse_foreach("r over 0..4", Span::default()).is_err());
        assert!(parse_foreach("", Span::default()).is_err());
    }

    #[test]
    fn parses_minimal_description() {
        let src = r#"
[arch]
name = "tiny${n}"

[params]
n = 2

[isa]
ops = ["add", "load"]

[fetch]
imem = "imem"
imem_read_latency = 1
imem_port_width = 2
ifs = "ifs"
ifs_latency = 1
issue_buffer = 4

[mapper]
family = "scalar"

[[execute_stage]]
name = "es[${i}]"
foreach = "i in 0..n"

[[functional_unit]]
name = "fu[${i}]"
in = "es[${i}]"
latency = 1
ops = ["add"]
foreach = "i in 0..n"
when = "i >= 0"

[[forward]]
from = "ifs"
to = "es[${i}]"
foreach = "i in 0..n"
"#;
        let d = parse(src).unwrap();
        assert_eq!(d.params.len(), 1);
        assert_eq!(d.isa.as_ref().unwrap().len(), 2);
        assert_eq!(d.decls.len(), 3);
        assert!(d.fetch.is_some());
        assert_eq!(d.mapper.as_ref().unwrap().node, "scalar");
        assert_eq!(d.decls[1].foreach.len(), 1);
        assert!(d.decls[1].when.is_some());
    }

    #[test]
    fn sweep_section_parses_dims_when_and_cap() {
        let src = "[arch]\nname = \"x\"\n[sweep]\nrows = \"2, 4, 8\"\ncols = \"2..17 step 2\"\n\
                   tile = 16\nwhen = \"rows <= cols\"\ncap = 100\n";
        let d = parse(src).unwrap();
        let s = d.sweep.unwrap();
        assert_eq!(s.dims.len(), 3);
        assert_eq!(s.dims[0].name.node, "rows");
        assert_eq!(s.dims[0].items.len(), 3);
        assert!(matches!(
            &s.dims[1].items[0],
            SweepItem::Range { step: Some(PExpr::Const(2)), .. }
        ));
        assert_eq!(s.dims[2].items, vec![SweepItem::Scalar(PExpr::Const(16))]);
        assert!(s.when.is_some());
        assert_eq!(s.cap.unwrap().node, 100);
        // duplicates, bad values, and a second [sweep] all error
        assert!(parse("[sweep]\nr = 1\nr = 2\n").is_err());
        assert!(parse("[sweep]\nwhen = 3\n").is_err());
        assert!(parse("[sweep]\ncap = \"x\"\n").is_err());
        assert!(parse("[sweep]\nr = [\"a\"]\n").is_err());
        assert!(parse("[sweep]\nr = 1\n[sweep]\nc = 2\n").is_err());
        assert!(parse("[sweep]\nr = \"\"\n").is_err());
        assert!(parse("[sweep]\nr = \"1..2..3\"\n").is_err());
    }

    #[test]
    fn sweep_items_respect_call_commas() {
        let items = parse_sweep_items("max(2, 4), 8, cdiv(n, 2)..n", Span::default()).unwrap();
        assert_eq!(items.len(), 3);
        assert!(matches!(&items[0], SweepItem::Scalar(PExpr::Call(..))));
        assert!(matches!(&items[2], SweepItem::Range { step: None, .. }));
    }

    #[test]
    fn duplicate_and_unknown_keys_error() {
        assert!(parse("[arch]\nname = \"a\"\nname = \"b\"\n").is_err());
        // an empty first [params] still makes the second a duplicate
        assert!(parse("[arch]\nname = \"a\"\n[params]\n[params]\nn = 1\n").is_err());
        assert!(parse("[arch]\nname = \"a\"\nbogus = 1\n").is_err());
        assert!(parse("[bogus_section]\nx = 1\n").is_err());
        assert!(parse("[arch]\n").is_err()); // missing required key
    }

    #[test]
    fn description_roundtrips_through_pretty_printer() {
        let src = r#"
[arch]
name = "sys${rows}x${cols}"

[params]
rows = 2
cols = 3

[isa]
ops = ["mac", "load", "store"]

[fetch]
imem = "imem"
imem_read_latency = 1
imem_port_width = "rows"
ifs = "ifs"
ifs_latency = 1
issue_buffer = 4

[mapper]
family = "scalar"

[[register_file]]
name = "pe[${r}][${c}].rf"
prefix = "pe[${r}][${c}]."
count = 4
foreach = "r in 0..rows, c in 0..cols"

[[memory]]
name = "dmem"
read_latency = "4"
write_latency = "imm0 + 4"
port_width = 2
max_concurrent = "rows + 2 * cols"
base = 0
words = 17179869184

[[execute_stage]]
name = "pe[${r}][${c}].es"
foreach = "r in 0..rows, c in 0..cols"

[[functional_unit]]
name = "pe[${r}][${c}].alu"
in = "pe[${r}][${c}].es"
latency = 1
ops = ["mac"]
foreach = "r in 0..rows, c in 0..cols"
when = "(r + c) % 2 == 0"

[[forward]]
from = "ifs"
to = "pe[${r}][${c}].es"
foreach = "r in 0..rows, c in 0..cols"
"#;
        let ast = parse(src).unwrap();
        let printed = ast.to_toml();
        let reparsed = parse(&printed).unwrap_or_else(|e| panic!("reparse failed: {e}\n{printed}"));
        assert_eq!(ast, reparsed, "pretty-printed form:\n{printed}");
    }
}
