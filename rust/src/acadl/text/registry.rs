//! The architecture registry: a content-keyed cache of compiled
//! descriptions, so hot paths (`acadl-perf serve` request loops, DSE sweeps
//! re-estimating the same described architecture) never re-lex, re-expand,
//! or re-finalize an unchanged description.
//!
//! Keys are the full description source (the map's hash is over the
//! content, and equality on the content rules out collisions). Compiled
//! models are shared as `Arc`s — the underlying `Diagram`'s route cache is
//! internally synchronized, so one compiled architecture can serve the
//! whole worker pool.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::Result;

use super::compile::{compile_source, CompiledArch};

/// Content-keyed cache of compiled architecture descriptions.
///
/// ```
/// use acadl_perf::acadl::text::ArchRegistry;
///
/// let source = std::fs::read_to_string("arch/gemmini_16.toml").unwrap();
/// let registry = ArchRegistry::new();
/// let compiled = registry.get_or_compile(&source, "arch/gemmini_16.toml").unwrap();
/// assert_eq!(compiled.name, "gemmini16x16");
/// // identical content never recompiles: one compile, one shared model
/// let again = registry.get_or_compile(&source, "arch/gemmini_16.toml").unwrap();
/// assert_eq!(registry.compile_count(), 1);
/// assert!(std::sync::Arc::ptr_eq(&compiled, &again));
/// ```
#[derive(Default)]
pub struct ArchRegistry {
    cache: Mutex<HashMap<Arc<str>, Arc<CompiledArch>>>,
    compiles: AtomicU64,
}

impl ArchRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide registry used by the coordinator.
    pub fn global() -> &'static ArchRegistry {
        static GLOBAL: OnceLock<ArchRegistry> = OnceLock::new();
        GLOBAL.get_or_init(ArchRegistry::new)
    }

    /// Compile `source` (or return the cached model for identical content).
    /// `origin` labels diagnostics, e.g. a file path or `<inline>`.
    /// Failed compiles are not cached.
    pub fn get_or_compile(&self, source: &str, origin: &str) -> Result<Arc<CompiledArch>> {
        if let Some(hit) = self.cache.lock().unwrap().get(source) {
            return Ok(Arc::clone(hit));
        }
        // compile outside the lock: a slow description must not stall
        // unrelated requests. Two racing misses both compile; the first
        // insert wins and both results are equivalent (compilation is
        // deterministic).
        self.compiles.fetch_add(1, Ordering::Relaxed);
        let compiled = Arc::new(compile_source(source, origin)?);
        let mut cache = self.cache.lock().unwrap();
        let entry = cache
            .entry(Arc::from(source))
            .or_insert_with(|| Arc::clone(&compiled));
        Ok(Arc::clone(entry))
    }

    /// Number of actual compilations performed (cache misses). The
    /// cache-hit test asserts this stays flat across repeated requests.
    pub fn compile_count(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Number of cached descriptions.
    pub fn len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all cached models (tests; memory pressure).
    pub fn clear(&self) {
        self.cache.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::compile::tests::TINY;
    use super::*;

    #[test]
    fn failed_compiles_are_not_cached() {
        // TINY has no [mapper], so compile_source fails at bind; errors
        // are never cached, so the counter moves on every attempt. (The
        // positive cache-hit path is covered by the described_archs
        // integration test against the shipped arch files.)
        let reg = ArchRegistry::new();
        assert!(reg.get_or_compile(TINY, "tiny").is_err());
        assert_eq!(reg.compile_count(), 1);
        assert!(reg.get_or_compile(TINY, "tiny").is_err());
        assert_eq!(reg.compile_count(), 2);
        assert!(reg.is_empty());
    }

}
