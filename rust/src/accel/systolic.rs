//! Parameterizable systolic array (paper §4.3, Figs. 3/4; evaluated in §7.3).
//!
//! An R×C grid of processing elements (PEs), each an ExecuteStage +
//! FunctionalUnit + RegisterFile (`in`, `in2`, `w`, `acc`). Load units sit on
//! the top row and leftmost column, store units on the bottom row, all
//! connected to a shared data memory:
//!
//! - left load unit `r`: scalar activation loads into `pe[r][0].in`
//! - top load unit `c`: weight-column loads (`loadw`, one transaction per
//!   `port_width` words — the Fig. 13 knob) and element-wise operand loads
//!   into column `c`'s registers
//! - PE (r,c): `mac` (reads `in`,`w` and the psum `acc` of the PE above,
//!   weight-stationary with psums flowing down), `mov_r` / `mov_d` data
//!   movement to the right/below neighbor, and the element-wise ops
//! - bottom store unit `c`: `store` (plain write) and `store_acc`
//!   (read-modify-write accumulation into the psum address)
//!
//! The instruction-memory port width merges fetch nodes in the AIDG (§6.1)
//! and determines `k_block` (eq. 3).

use anyhow::Result;

use crate::acadl::{Diagram, Latency};
use crate::ids::{Addr, ObjId, OpId, RegId};

/// Address-space bases within the single data memory.
pub const ACT_BASE: Addr = 0;
/// Weight region base.
pub const WEIGHT_BASE: Addr = 1 << 32;
/// Partial-sum region base.
pub const PSUM_BASE: Addr = 2 << 32;
/// Output region base.
pub const OUT_BASE: Addr = 3 << 32;
const MEM_WORDS: u64 = 4 << 32;

/// Configuration of a systolic array instance.
#[derive(Debug, Clone, Copy)]
pub struct SystolicConfig {
    /// Array rows.
    pub rows: u32,
    /// Array columns.
    pub cols: u32,
    /// Data-memory port width (words per transaction) — the Fig. 13 sweep.
    pub port_width: u32,
    /// Data-memory transaction latencies.
    pub mem_read_latency: u64,
    /// Data-memory write transaction latency.
    pub mem_write_latency: u64,
    /// Concurrent memory transactions (banked SRAM ports).
    pub mem_concurrency: u32,
    /// Instruction-memory port width (instructions per fetch).
    pub imem_port_width: u32,
    /// Issue buffer size of the fetch stage.
    pub issue_buffer: u32,
}

impl SystolicConfig {
    /// A `rows`×`cols` array with default memory parameters.
    pub fn new(rows: u32, cols: u32) -> Self {
        Self {
            rows,
            cols,
            port_width: 2,
            mem_read_latency: 4,
            mem_write_latency: 4,
            // one port per peripheral unit: left loads + top loads + stores
            mem_concurrency: rows + 2 * cols,
            imem_port_width: 2,
            issue_buffer: 4,
        }
    }

    /// Set the data-memory port width (builder style).
    pub fn with_port_width(mut self, pw: u32) -> Self {
        self.port_width = pw;
        self
    }
}

/// Per-PE register ids.
#[derive(Debug, Clone, Copy)]
pub struct PeRegs {
    /// Input register (left-streamed operand).
    pub r_in: RegId,
    /// Second-operand register (element-wise ops).
    pub r_in2: RegId,
    /// Weight register.
    pub r_w: RegId,
    /// Accumulator register.
    pub r_acc: RegId,
}

/// Interned operation ids of the systolic ISA.
#[derive(Debug, Clone, Copy)]
pub struct SystolicOps {
    /// Load a word from memory into a PE register.
    pub load: OpId,
    /// Load a weight.
    pub loadw: OpId,
    /// Load an element-wise operand.
    pub loade: OpId,
    /// Load the second element-wise operand.
    pub loade2: OpId,
    /// Route an operand to the right neighbor PE.
    pub mov_r: OpId,
    /// Route an operand to the neighbor PE below.
    pub mov_d: OpId,
    /// Multiply-accumulate.
    pub mac: OpId,
    /// Element-wise ReLU.
    pub ew_relu: OpId,
    /// Element-wise clip.
    pub ew_clip: OpId,
    /// Element-wise add.
    pub ew_add: OpId,
    /// Element-wise multiply.
    pub ew_mul: OpId,
    /// Element-wise accumulate.
    pub ew_acc: OpId,
    /// Element-wise multiply-accumulate.
    pub ew_mac: OpId,
    /// Store a PE register to memory.
    pub store: OpId,
    /// Store the accumulator to memory.
    pub store_acc: OpId,
}

/// The instantiated model: diagram + handles the mapper needs.
pub struct Systolic {
    /// The ACADL object diagram.
    pub diagram: Diagram,
    /// Instantiation configuration.
    pub cfg: SystolicConfig,
    /// Interned ISA handles.
    pub ops: SystolicOps,
    /// `pe[r][c]` register ids.
    pub pe: Vec<Vec<PeRegs>>,
}

impl Systolic {
    /// Build the ACADL object diagram for an R×C systolic array.
    pub fn new(cfg: SystolicConfig) -> Result<Self> {
        assert!(cfg.rows >= 1 && cfg.cols >= 1);
        let mut d = Diagram::new(format!("systolic{}x{}", cfg.rows, cfg.cols));
        let (_imem, ifs) = d.add_fetch(
            "instructionMemory",
            1,
            cfg.imem_port_width,
            "instructionFetchStage",
            1,
            cfg.issue_buffer,
        );
        let dmem = d.add_memory(
            "dataMemory",
            cfg.mem_read_latency,
            cfg.mem_write_latency,
            cfg.port_width,
            cfg.mem_concurrency,
            0,
            MEM_WORDS,
        );

        let ops = SystolicOps {
            load: d.op("load"),
            loadw: d.op("loadw"),
            loade: d.op("loade"),
            loade2: d.op("loade2"),
            mov_r: d.op("mov_r"),
            mov_d: d.op("mov_d"),
            mac: d.op("mac"),
            ew_relu: d.op("ew_relu"),
            ew_clip: d.op("ew_clip"),
            ew_add: d.op("ew_add"),
            ew_mul: d.op("ew_mul"),
            ew_acc: d.op("ew_acc"),
            ew_mac: d.op("ew_mac"),
            store: d.op("store"),
            store_acc: d.op("store_acc"),
        };

        // PE grid: regfile + execute stage + functional unit each
        let mut pe_regs: Vec<Vec<PeRegs>> = Vec::new();
        let mut pe_rf: Vec<Vec<ObjId>> = Vec::new();
        let mut pe_fu: Vec<Vec<ObjId>> = Vec::new();
        for r in 0..cfg.rows {
            let mut regs_row = Vec::new();
            let mut rf_row = Vec::new();
            let mut fu_row = Vec::new();
            for c in 0..cfg.cols {
                let (rf, regs) =
                    d.add_regfile(&format!("pe[{r}][{c}].rf"), &format!("pe[{r}][{c}]."), 4);
                let es = d.add_execute_stage(&format!("pe[{r}][{c}].es"));
                let fu = d.add_fu(
                    es,
                    &format!("pe[{r}][{c}].alu"),
                    Latency::Fixed(1),
                    &[
                        "mac", "mov_r", "mov_d", "ew_relu", "ew_clip", "ew_add", "ew_mul",
                        "ew_acc", "ew_mac",
                    ],
                );
                d.forward(ifs, es);
                regs_row.push(PeRegs {
                    r_in: regs[0],
                    r_in2: regs[1],
                    r_w: regs[2],
                    r_acc: regs[3],
                });
                rf_row.push(rf);
                fu_row.push(fu);
            }
            pe_regs.push(regs_row);
            pe_rf.push(rf_row);
            pe_fu.push(fu_row);
        }

        // PE register access: own RF read+write; read the PE above (psum
        // chain); write the right neighbor (mov_r) and the PE below (mov_d).
        for r in 0..cfg.rows as usize {
            for c in 0..cfg.cols as usize {
                let fu = pe_fu[r][c];
                d.fu_reads(fu, pe_rf[r][c]);
                d.fu_writes(fu, pe_rf[r][c]);
                if r > 0 {
                    d.fu_reads(fu, pe_rf[r - 1][c]);
                }
                if c + 1 < cfg.cols as usize {
                    d.fu_writes(fu, pe_rf[r][c + 1]);
                }
                if r + 1 < cfg.rows as usize {
                    d.fu_writes(fu, pe_rf[r + 1][c]);
                }
            }
        }

        // left load units (one per row): scalar activation loads
        for r in 0..cfg.rows as usize {
            let es = d.add_execute_stage(&format!("memoryLoadUnit[{r}][left].es"));
            let fu = d.add_fu(
                es,
                &format!("memoryLoadUnit[{r}][left]"),
                Latency::Fixed(1),
                &["load"],
            );
            d.forward(ifs, es);
            d.fu_writes(fu, pe_rf[r][0]);
            d.mem_reads(fu, dmem);
        }

        // top load units (one per column): weight-column + element-wise loads
        for c in 0..cfg.cols as usize {
            let es = d.add_execute_stage(&format!("memoryLoadUnit[top][{c}].es"));
            let fu = d.add_fu(
                es,
                &format!("memoryLoadUnit[top][{c}]"),
                Latency::Fixed(1),
                &["loadw", "loade", "loade2"],
            );
            d.forward(ifs, es);
            for rf_row in pe_rf.iter() {
                d.fu_writes(fu, rf_row[c]);
            }
            d.mem_reads(fu, dmem);
        }

        // bottom store units (one per column)
        for c in 0..cfg.cols as usize {
            let es = d.add_execute_stage(&format!("memoryStoreUnit[{c}].es"));
            let fu = d.add_fu(
                es,
                &format!("memoryStoreUnit[{c}]"),
                Latency::Fixed(1),
                &["store", "store_acc"],
            );
            d.forward(ifs, es);
            d.fu_reads(fu, pe_rf[cfg.rows as usize - 1][c]);
            d.mem_reads(fu, dmem); // store_acc reads the psum before accumulating
            d.mem_writes(fu, dmem);
        }

        d.finalize()?;
        Ok(Self { diagram: d, cfg, ops, pe: pe_regs })
    }

    /// Bind a description-compiled diagram (see [`crate::acadl::text`]) to
    /// the scalar-mapper handles, resolving ops and per-PE registers by
    /// name. The description must follow the builder's naming scheme
    /// (`pe[r][c].rf` register files with prefix `pe[r][c].`, ops
    /// `load`/`mac`/... — see `arch/systolic_16x16.toml`).
    pub fn from_described(diagram: Diagram, cfg: SystolicConfig) -> Result<Self> {
        anyhow::ensure!(cfg.rows >= 1 && cfg.cols >= 1, "systolic grid must be at least 1x1");
        let what = "described systolic diagram";
        let op = |name: &str| diagram.require_op(name, what);
        let ops = SystolicOps {
            load: op("load")?,
            loadw: op("loadw")?,
            loade: op("loade")?,
            loade2: op("loade2")?,
            mov_r: op("mov_r")?,
            mov_d: op("mov_d")?,
            mac: op("mac")?,
            ew_relu: op("ew_relu")?,
            ew_clip: op("ew_clip")?,
            ew_add: op("ew_add")?,
            ew_mul: op("ew_mul")?,
            ew_acc: op("ew_acc")?,
            ew_mac: op("ew_mac")?,
            store: op("store")?,
            store_acc: op("store_acc")?,
        };
        let mut pe_regs: Vec<Vec<PeRegs>> = Vec::with_capacity(cfg.rows as usize);
        for r in 0..cfg.rows {
            let mut row = Vec::with_capacity(cfg.cols as usize);
            for c in 0..cfg.cols {
                let reg = |i: u32| diagram.require_reg(&format!("pe[{r}][{c}].{i}"), what);
                row.push(PeRegs { r_in: reg(0)?, r_in2: reg(1)?, r_w: reg(2)?, r_acc: reg(3)? });
            }
            pe_regs.push(row);
        }
        Ok(Self { diagram, cfg, ops, pe: pe_regs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instruction;

    #[test]
    fn builds_2x2() {
        let s = Systolic::new(SystolicConfig::new(2, 2)).unwrap();
        // fetch(2) + 4 PEs × 3 + 2 left + 2 top + 2 stores (×2 objs each) +
        // dmem + writeBack
        assert!(s.diagram.num_objects() > 20);
        assert_eq!(s.pe.len(), 2);
        assert_eq!(s.pe[0].len(), 2);
    }

    #[test]
    fn mac_routes_to_its_pe() {
        let s = Systolic::new(SystolicConfig::new(2, 2)).unwrap();
        let p = s.pe[1][1];
        let above = s.pe[0][1];
        let i = Instruction::new(s.ops.mac)
            .reads(&[p.r_in, p.r_w, above.r_acc])
            .writes(&[p.r_acc]);
        let r = s.diagram.route(&i).unwrap();
        assert_eq!(s.diagram.object(r.fu).name, "pe[1][1].alu");
    }

    #[test]
    fn load_routes_to_left_unit() {
        let s = Systolic::new(SystolicConfig::new(2, 2)).unwrap();
        let i = Instruction::new(s.ops.load).writes(&[s.pe[1][0].r_in]).read_mem(&[ACT_BASE + 5]);
        let r = s.diagram.route(&i).unwrap();
        assert_eq!(s.diagram.object(r.fu).name, "memoryLoadUnit[1][left]");
        assert!(r.has_writeback);
    }

    #[test]
    fn loadw_routes_to_top_unit_of_column() {
        let s = Systolic::new(SystolicConfig::new(3, 3)).unwrap();
        let col = 2usize;
        let writes: Vec<RegId> = (0..3).map(|r| s.pe[r][col].r_w).collect();
        let addrs: Vec<Addr> = (0..3).map(|r| WEIGHT_BASE + r as u64).collect();
        let i = Instruction::new(s.ops.loadw).writes(&writes).read_mem(&addrs);
        let r = s.diagram.route(&i).unwrap();
        assert_eq!(s.diagram.object(r.fu).name, "memoryLoadUnit[top][2]");
    }

    #[test]
    fn store_acc_reads_and_writes_memory() {
        let s = Systolic::new(SystolicConfig::new(2, 2)).unwrap();
        let i = Instruction::new(s.ops.store_acc)
            .reads(&[s.pe[1][0].r_acc])
            .read_mem(&[PSUM_BASE + 7])
            .write_mem(&[PSUM_BASE + 7]);
        let r = s.diagram.route(&i).unwrap();
        assert_eq!(s.diagram.object(r.fu).name, "memoryStoreUnit[0]");
        assert_eq!(r.read_mems.len(), 1);
        assert_eq!(r.write_mems.len(), 1);
    }

    #[test]
    fn mov_r_crosses_pe_boundary() {
        let s = Systolic::new(SystolicConfig::new(2, 2)).unwrap();
        let i = Instruction::new(s.ops.mov_r)
            .reads(&[s.pe[0][0].r_in])
            .writes(&[s.pe[0][1].r_in]);
        let r = s.diagram.route(&i).unwrap();
        assert_eq!(s.diagram.object(r.fu).name, "pe[0][0].alu");
    }

    #[test]
    fn rightmost_pe_cannot_move_right() {
        let s = Systolic::new(SystolicConfig::new(2, 2)).unwrap();
        // no PE has write access beyond the grid; routing must fail
        let i = Instruction::new(s.ops.mov_r)
            .reads(&[s.pe[0][1].r_in])
            .writes(&[s.pe[0][0].r_in]); // wrong direction: no FU writes left
        assert!(s.diagram.route(&i).is_err());
    }
}
