//! ACADL object-diagram builders for the paper's four accelerator
//! architectures (§4.3, §7), each at its own abstraction level:
//!
//! | Model | Level | Paper section |
//! |---|---|---|
//! | [`systolic`] | scalar `load`/`mac`/`store` | §4.3 Fig. 3/4, §7.3 |
//! | [`ultratrail`] | fused `conv_ext` tensor ops | §4.3 Fig. 5/6, §7.1 |
//! | [`gemmini`] | tiled-GEMM `mvin`/`preload`/`compute`/`mvout` | §7.2 Fig. 10 |
//! | [`plasticine`] | parallel tiled GEMM across PCUs | §7.4 Fig. 14 |

pub mod gemmini;
pub mod plasticine;
pub mod systolic;
pub mod ultratrail;

pub use gemmini::{Gemmini, GemminiConfig};
pub use plasticine::{Plasticine, PlasticineConfig};
pub use systolic::{Systolic, SystolicConfig};
pub use ultratrail::{UltraTrail, UltraTrailConfig};
