//! Plasticine-derived reconfigurable architecture modeled at the matrix
//! operation level (paper §7.4, Fig. 14).
//!
//! A rows×cols checkerboard of **Pattern Compute Units** (PCUs) and
//! **Pattern Memory Units** (PMUs) connected by a switch-box interconnect:
//!
//! - each PCU is an ExecuteStage + FunctionalUnit executing tiled GEMM /
//!   matrix-add instructions (with fused activation/pooling) on its SIMD
//!   pipeline, plus input/output RegisterFiles for the staged tiles;
//! - each PMU is a scratchpad Memory;
//! - each PCU's switch port is an ExecuteStage + FunctionalUnit moving
//!   tiles PMU → PCU input registers (`route_in`) and PCU output register →
//!   PMU (`route_out`); the per-instruction immediate `imm1` carries the
//!   Manhattan hop distance of the route, charged one cycle per hop per
//!   multi-word beat.
//!
//! Tile-op immediates: `imm0` = tile dimension T (latency is evaluated per
//! instruction so one diagram serves every tile size ≤ the configured
//! maximum), `imm1` = hop count (routing ops only).

use anyhow::Result;

use crate::acadl::{Diagram, Latency};
use crate::ids::{Addr, ObjId, OpId, RegId};

/// PMU token-address region size.
pub const PMU_REGION_WORDS: u64 = 1 << 24;
/// Base of the PMU token address space (PMU `i` claims
/// `[PMU_BASE + i·REGION, …)`).
pub const PMU_BASE: Addr = 0;

/// Plasticine-derived instance configuration.
#[derive(Debug, Clone, Copy)]
pub struct PlasticineConfig {
    /// Checkerboard rows.
    pub rows: u32,
    /// Checkerboard columns.
    pub cols: u32,
    /// PCU GEMM tile dimension T (the Fig. 15 DSE axis).
    pub tile: u32,
    /// SIMD lanes per PCU pipeline.
    pub simd_lanes: u32,
    /// PCU pipeline depth (fill cycles per tile op).
    pub pipe_depth: u32,
    /// Words moved per switch-hop cycle.
    pub switch_width: u32,
    /// Instruction memory port width.
    pub imem_port_width: u32,
    /// Issue-buffer size of the fetch stage.
    pub issue_buffer: u32,
}

impl PlasticineConfig {
    /// A `rows`×`cols` grid with PCU tile size `tile` and default
    /// microarchitecture parameters.
    pub fn new(rows: u32, cols: u32, tile: u32) -> Self {
        Self {
            rows,
            cols,
            tile,
            simd_lanes: 16,
            pipe_depth: 6,
            switch_width: 4,
            imem_port_width: 2,
            issue_buffer: 8,
        }
    }
}

/// Interned Plasticine ISA ops.
#[derive(Debug, Clone, Copy)]
pub struct PlasticineOps {
    /// T×T×T GEMM tile (fused activation on the SIMD tail).
    pub gemm_tile: OpId,
    /// T×T element-wise add tile.
    pub add_tile: OpId,
    /// PMU → PCU input-register tile move.
    pub route_in: OpId,
    /// PCU output register → PMU tile move.
    pub route_out: OpId,
}

/// One instantiated PCU's handles.
#[derive(Debug, Clone, Copy)]
pub struct Pcu {
    /// Grid position (row, col) for hop-distance computation.
    pub pos: (u32, u32),
    /// A-operand tile register.
    pub r_a: RegId,
    /// B-operand tile register.
    pub r_b: RegId,
    /// Output tile register.
    pub r_out: RegId,
}

/// One instantiated PMU's handles.
#[derive(Debug, Clone, Copy)]
pub struct Pmu {
    /// Grid position (row, col).
    pub pos: (u32, u32),
    /// The PMU's memory object.
    pub mem: ObjId,
    /// Token-address base of this PMU.
    pub base: Addr,
}

/// The instantiated Plasticine-derived model.
pub struct Plasticine {
    /// The ACADL object diagram.
    pub diagram: Diagram,
    /// Instantiation configuration.
    pub cfg: PlasticineConfig,
    /// Interned ISA handles.
    pub ops: PlasticineOps,
    /// Compute units in grid order.
    pub pcus: Vec<Pcu>,
    /// Memory units in grid order.
    pub pmus: Vec<Pmu>,
}

impl Plasticine {
    /// Mirror of the PCU tile-GEMM latency expression.
    pub fn gemm_tile_cycles(cfg: &PlasticineConfig, t: u32) -> u64 {
        (t as u64 * t as u64 * t as u64).div_ceil(cfg.simd_lanes as u64) + cfg.pipe_depth as u64
    }

    /// Mirror of the tile-add latency expression.
    pub fn add_tile_cycles(cfg: &PlasticineConfig, t: u32) -> u64 {
        (t as u64 * t as u64).div_ceil(cfg.simd_lanes as u64) + cfg.pipe_depth as u64
    }

    /// Mirror of the switch-route latency expression (tile of T² words over
    /// `hops` switch hops, `switch_width` words per beat).
    pub fn route_cycles(cfg: &PlasticineConfig, t: u32, hops: u32) -> u64 {
        let beats = (t as u64 * t as u64).div_ceil(cfg.switch_width as u64);
        beats + hops as u64
    }

    /// Manhattan distance between two grid positions.
    pub fn hops(a: (u32, u32), b: (u32, u32)) -> u32 {
        a.0.abs_diff(b.0) + a.1.abs_diff(b.1)
    }

    /// Build the Fig. 14 ACADL object diagram.
    pub fn new(cfg: PlasticineConfig) -> Result<Self> {
        if cfg.rows < 1 || cfg.cols < 1 || cfg.rows * cfg.cols < 2 {
            anyhow::bail!("grid {}x{} too small (need at least one PCU and one PMU)", cfg.rows, cfg.cols);
        }
        assert!(cfg.tile >= 1);
        let mut d = Diagram::new(format!(
            "plasticine{}x{}t{}",
            cfg.rows, cfg.cols, cfg.tile
        ));
        let (_imem, ifs) = d.add_fetch(
            "instructionMemory",
            1,
            cfg.imem_port_width,
            "instructionFetchStage",
            1,
            cfg.issue_buffer,
        );

        let ops = PlasticineOps {
            gemm_tile: d.op("gemm_tile"),
            add_tile: d.op("add_tile"),
            route_in: d.op("route_in"),
            route_out: d.op("route_out"),
        };

        // PMUs first (memories must exist before switch FU associations)
        let mut pmus = Vec::new();
        for r in 0..cfg.rows {
            for c in 0..cfg.cols {
                if (r + c) % 2 == 1 {
                    let i = pmus.len();
                    let base = PMU_BASE + i as u64 * PMU_REGION_WORDS;
                    // banked scratchpad: serves several switch transactions
                    // concurrently (capacity-1 objects would serialize the
                    // parallel PCU streams in program order — the paper's
                    // "last structure user" rule — where real banked PMUs
                    // arbitrate by arrival)
                    let mem = d.add_memory(
                        &format!("pmu[{r}][{c}]"),
                        1,
                        1,
                        cfg.switch_width,
                        4,
                        base,
                        PMU_REGION_WORDS,
                    );
                    pmus.push(Pmu { pos: (r, c), mem, base });
                }
            }
        }
        if pmus.is_empty() {
            anyhow::bail!("grid {}x{} yields no PMUs", cfg.rows, cfg.cols);
        }

        let gemm_expr = format!(
            "cdiv(imm0 * imm0 * imm0, {lanes}) + {depth}",
            lanes = cfg.simd_lanes,
            depth = cfg.pipe_depth
        );
        let add_expr = format!(
            "cdiv(imm0 * imm0, {lanes}) + {depth}",
            lanes = cfg.simd_lanes,
            depth = cfg.pipe_depth
        );
        let route_expr = format!("cdiv(imm0 * imm0, {w}) + imm1", w = cfg.switch_width);

        let mut pcus = Vec::new();
        for r in 0..cfg.rows {
            for c in 0..cfg.cols {
                if (r + c) % 2 == 0 {
                    let i = pcus.len();
                    let (rf_in, in_regs) =
                        d.add_regfile(&format!("pcu[{r}][{c}].in"), &format!("pcu{i}.in"), 2);
                    let (rf_out, out_regs) =
                        d.add_regfile(&format!("pcu[{r}][{c}].out"), &format!("pcu{i}.out"), 1);

                    let es = d.add_execute_stage(&format!("pcu[{r}][{c}].es"));
                    let fu = d.add_fu(
                        es,
                        &format!("pcu[{r}][{c}].simd"),
                        Latency::Expr(crate::acadl::Expr::parse(&gemm_expr)?),
                        &["gemm_tile"],
                    );
                    let add_fu = d.add_fu(
                        es,
                        &format!("pcu[{r}][{c}].simd.add"),
                        Latency::Expr(crate::acadl::Expr::parse(&add_expr)?),
                        &["add_tile"],
                    );
                    d.forward(ifs, es);
                    for f in [fu, add_fu] {
                        d.fu_reads(f, rf_in);
                        d.fu_reads(f, rf_out); // accumulate onto own output
                        d.fu_writes(f, rf_out);
                    }

                    // switch port: PMU <-> PCU tile moves
                    let sw_es = d.add_execute_stage(&format!("switch[{r}][{c}].es"));
                    let sw = d.add_fu(
                        sw_es,
                        &format!("switch[{r}][{c}]"),
                        Latency::Expr(crate::acadl::Expr::parse(&route_expr)?),
                        &["route_in", "route_out"],
                    );
                    d.forward(ifs, sw_es);
                    d.fu_writes(sw, rf_in);
                    d.fu_reads(sw, rf_out);
                    for pmu in &pmus {
                        d.mem_reads(sw, pmu.mem);
                        d.mem_writes(sw, pmu.mem);
                    }

                    pcus.push(Pcu {
                        pos: (r, c),
                        r_a: in_regs[0],
                        r_b: in_regs[1],
                        r_out: out_regs[0],
                    });
                }
            }
        }
        if pcus.is_empty() {
            anyhow::bail!("grid {}x{} yields no PCUs", cfg.rows, cfg.cols);
        }

        d.finalize()?;
        Ok(Self { diagram: d, cfg, ops, pcus, pmus })
    }

    /// Bind a description-compiled diagram (see [`crate::acadl::text`]) to
    /// the Plasticine-mapper handles. The checkerboard is re-walked in the
    /// builder's row-major order, so PCU ordinals (register prefixes
    /// `pcu{i}.in` / `pcu{i}.out`) line up with [`Plasticine::new`]; PMU
    /// token bases are taken from the address range each compiled memory
    /// actually claims — see `arch/plasticine_3x6.toml`.
    pub fn from_described(diagram: Diagram, cfg: PlasticineConfig) -> Result<Self> {
        if cfg.rows < 1 || cfg.cols < 1 || cfg.rows * cfg.cols < 2 {
            anyhow::bail!(
                "grid {}x{} too small (need at least one PCU and one PMU)",
                cfg.rows,
                cfg.cols
            );
        }
        anyhow::ensure!(cfg.tile >= 1, "tile must be >= 1");
        let what = "described plasticine diagram";
        let op = |name: &str| diagram.require_op(name, what);
        let ops = PlasticineOps {
            gemm_tile: op("gemm_tile")?,
            add_tile: op("add_tile")?,
            route_in: op("route_in")?,
            route_out: op("route_out")?,
        };
        let reg = |name: String| diagram.require_reg(&name, what);
        let mut pcus = Vec::new();
        let mut pmus = Vec::new();
        for r in 0..cfg.rows {
            for c in 0..cfg.cols {
                if (r + c) % 2 == 1 {
                    let name = format!("pmu[{r}][{c}]");
                    let mem = diagram.require_memory(&name, what)?;
                    // the token base is whatever address range the compiled
                    // description actually claims for this PMU — assuming
                    // the builder's row-major numbering here would silently
                    // mis-route traffic for reordered descriptions
                    let base = match &diagram.object(mem).kind {
                        crate::acadl::ObjectKind::Memory { address_ranges, .. } => {
                            use anyhow::Context as _;
                            address_ranges.first().map(|r| r.0).with_context(|| {
                                format!("{what}: memory `{name}` claims no address range")
                            })?
                        }
                        _ => unreachable!("require_memory checked the kind"),
                    };
                    pmus.push(Pmu { pos: (r, c), mem, base });
                } else {
                    let i = pcus.len();
                    pcus.push(Pcu {
                        pos: (r, c),
                        r_a: reg(format!("pcu{i}.in0"))?,
                        r_b: reg(format!("pcu{i}.in1"))?,
                        r_out: reg(format!("pcu{i}.out0"))?,
                    });
                }
            }
        }
        anyhow::ensure!(!pcus.is_empty(), "grid {}x{} yields no PCUs", cfg.rows, cfg.cols);
        anyhow::ensure!(!pmus.is_empty(), "grid {}x{} yields no PMUs", cfg.rows, cfg.cols);
        Ok(Self { diagram, cfg, ops, pcus, pmus })
    }

    /// Nearest PMU (by hop distance) to PCU `p`, with the distance.
    pub fn nearest_pmu(&self, p: usize) -> (usize, u32) {
        let pos = self.pcus[p].pos;
        self.pmus
            .iter()
            .enumerate()
            .map(|(i, m)| (i, Self::hops(pos, m.pos)))
            .min_by_key(|&(_, h)| h)
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instruction;

    #[test]
    fn checkerboard_split() {
        let p = Plasticine::new(PlasticineConfig::new(3, 6, 16)).unwrap();
        assert_eq!(p.pcus.len(), 9);
        assert_eq!(p.pmus.len(), 9);
        let p2 = Plasticine::new(PlasticineConfig::new(2, 2, 8)).unwrap();
        assert_eq!(p2.pcus.len(), 2);
        assert_eq!(p2.pmus.len(), 2);
    }

    #[test]
    fn latency_mirrors() {
        let cfg = PlasticineConfig::new(2, 2, 16);
        assert_eq!(Plasticine::gemm_tile_cycles(&cfg, 16), 4096 / 16 + 6);
        assert_eq!(Plasticine::add_tile_cycles(&cfg, 16), 16 + 6);
        assert_eq!(Plasticine::route_cycles(&cfg, 16, 3), 64 + 3);
    }

    #[test]
    fn gemm_expr_matches_mirror() {
        let p = Plasticine::new(PlasticineConfig::new(2, 2, 16)).unwrap();
        let pcu = p.pcus[0];
        let i = Instruction::new(p.ops.gemm_tile)
            .reads(&[pcu.r_a, pcu.r_b])
            .writes(&[pcu.r_out])
            .imms(&[16]);
        let r = p.diagram.route(&i).unwrap();
        if let crate::acadl::ObjectKind::FunctionalUnit { latency, .. } =
            &p.diagram.object(r.fu).kind
        {
            assert_eq!(latency.eval(&i), Plasticine::gemm_tile_cycles(&p.cfg, 16));
        } else {
            panic!("not an FU");
        }
    }

    #[test]
    fn route_in_reads_pmu_writes_pcu() {
        let p = Plasticine::new(PlasticineConfig::new(3, 6, 8)).unwrap();
        let pcu = p.pcus[2];
        let (pm, hops) = p.nearest_pmu(2);
        let i = Instruction::new(p.ops.route_in)
            .writes(&[pcu.r_a])
            .read_mem(&[p.pmus[pm].base + 7])
            .imms(&[8, hops as i64]);
        let r = p.diagram.route(&i).unwrap();
        assert!(p.diagram.object(r.fu).name.starts_with("switch"));
        assert!(r.has_writeback);
    }

    #[test]
    fn pcus_have_independent_locks() {
        let p = Plasticine::new(PlasticineConfig::new(2, 2, 8)).unwrap();
        let (a, b) = (p.pcus[0], p.pcus[1]);
        let ia = Instruction::new(p.ops.gemm_tile).reads(&[a.r_a, a.r_b]).writes(&[a.r_out]).imms(&[8]);
        let ib = Instruction::new(p.ops.gemm_tile).reads(&[b.r_a, b.r_b]).writes(&[b.r_out]).imms(&[8]);
        let ra = p.diagram.route(&ia).unwrap();
        let rb = p.diagram.route(&ib).unwrap();
        assert_ne!(p.diagram.lock(ra.fu).owner, p.diagram.lock(rb.fu).owner);
    }

    #[test]
    fn hops_manhattan() {
        assert_eq!(Plasticine::hops((0, 0), (2, 3)), 5);
        assert_eq!(Plasticine::hops((1, 1), (1, 1)), 0);
    }

    #[test]
    fn degenerate_grids_rejected() {
        assert!(Plasticine::new(PlasticineConfig::new(1, 1, 8)).is_err());
    }
}
