//! Gemmini — UC Berkeley's parameterizable GEMM accelerator modeled at the
//! tiled-GEMM level (paper §7.2, Fig. 10).
//!
//! Architecture captured from the block diagram:
//!
//! - a DIM×DIM systolic MAC array fed by a **scratchpad** (banked SRAM,
//!   GEMM inputs A/B/D) and an **accumulator** SRAM (output C),
//! - a **DMA engine** between DRAM (the SoC L2 in the real system) and the
//!   SRAMs,
//! - a **decoupled access-execute** split: the reorder buffer issues
//!   `mvin`/`mvout` to the DMA controller and `preload`/`compute` to the
//!   array controller as soon as their dependencies resolve.
//!
//! The decoupling is modeled as two parallel ExecuteStages (`dma_engine0`,
//! `gemmini0`) whose sibling-FU structural locks serialize DMA transfers
//! against each other and array ops against each other — while DMA and
//! compute overlap freely, dependency-limited, exactly like the ROB. Hazards
//! between instructions are the AIDG's data dependencies over scratchpad /
//! accumulator *tile tokens* (one address per DIM×DIM tile).
//!
//! The DRAM read latency is a *linear burst model* over the accessed data
//! volume and start address (paper §7.2): `mvin` carries
//! `imm0 = volume (words)` and `imm1 = start address` and the memory's
//! latency expression charges `base + volume/words-per-beat + row-open`
//! cycles.

use anyhow::Result;

use crate::acadl::{Diagram, Latency};
use crate::ids::{Addr, ObjId, OpId};

/// DRAM token space (one token per DIM×DIM tile of each operand).
pub const DRAM_BASE: Addr = 0;
/// Scratchpad token space.
pub const SPAD_BASE: Addr = 1 << 40;
/// Accumulator token space.
pub const ACC_BASE: Addr = 2 << 40;
const REGION_WORDS: u64 = 1 << 40;

/// Gemmini instance configuration.
#[derive(Debug, Clone, Copy)]
pub struct GemminiConfig {
    /// Systolic array dimension (the paper instantiates DIM = 16).
    pub dim: u32,
    /// DRAM burst-model parameters: fixed cost per transaction.
    pub dram_base_latency: u64,
    /// Words transferred per DRAM beat.
    pub dram_words_per_beat: u64,
    /// Row-open granularity for the start-address term.
    pub dram_row_words: u64,
    /// Instruction memory port width (RoCC command queue width).
    pub imem_port_width: u32,
    /// Issue buffer (reorder buffer) size.
    pub issue_buffer: u32,
}

impl Default for GemminiConfig {
    fn default() -> Self {
        Self {
            dim: 16,
            dram_base_latency: 12,
            dram_words_per_beat: 8,
            dram_row_words: 256,
            imem_port_width: 2,
            issue_buffer: 8,
        }
    }
}

impl GemminiConfig {
    /// Set the array dimension (builder style).
    pub fn with_dim(mut self, dim: u32) -> Self {
        self.dim = dim;
        self
    }
}

/// Interned Gemmini ISA ops (named after the real `gemmini_*` intrinsics).
#[derive(Debug, Clone, Copy)]
pub struct GemminiOps {
    /// Execute-pipeline configuration.
    pub config_ex: OpId,
    /// Load-path configuration.
    pub config_ld: OpId,
    /// Store-path configuration.
    pub config_st: OpId,
    /// DRAM → scratchpad tile move.
    pub mvin: OpId,
    /// DRAM → accumulator tile move (bias / residual operand).
    pub mvin_acc: OpId,
    /// Accumulator → DRAM tile move (applies activation/pooling on the way
    /// out when configured — the fusion path).
    pub mvout: OpId,
    /// Load the B tile into the array.
    pub preload: OpId,
    /// A·B into a fresh accumulator tile.
    pub compute_preloaded: OpId,
    /// A·B accumulated onto an existing accumulator tile.
    pub compute_accumulated: OpId,
}

/// The instantiated Gemmini model.
pub struct Gemmini {
    /// The ACADL object diagram.
    pub diagram: Diagram,
    /// Instantiation configuration.
    pub cfg: GemminiConfig,
    /// Interned ISA handles.
    pub ops: GemminiOps,
    /// DRAM object.
    pub dram: ObjId,
    /// Scratchpad object.
    pub spad: ObjId,
    /// Accumulator object.
    pub acc: ObjId,
    /// Array state register written by `preload`, read by `compute_*`.
    pub b_tile_reg: crate::ids::RegId,
    /// Config register written by `config_*`, read by array + DMA ops.
    pub cfg_reg: crate::ids::RegId,
}

impl Gemmini {
    /// Mirror of the DRAM burst read-latency expression (tests + baselines).
    pub fn dram_read_cycles(cfg: &GemminiConfig, volume_words: u64, start_addr: u64) -> u64 {
        cfg.dram_base_latency
            + volume_words.div_ceil(cfg.dram_words_per_beat)
            + (start_addr % cfg.dram_row_words) / cfg.dram_words_per_beat
    }

    /// Array occupancy of one DIM×DIM×DIM compute: DIM rows streamed through
    /// a pipeline ~2·DIM deep.
    pub fn compute_cycles(dim: u32) -> u64 {
        3 * dim as u64 + 2
    }

    /// Array occupancy of a preload (B tile streamed in column-wise).
    pub fn preload_cycles(dim: u32) -> u64 {
        dim as u64 + 2
    }

    /// Build the Fig. 10 ACADL object diagram.
    pub fn new(cfg: GemminiConfig) -> Result<Self> {
        assert!(cfg.dim >= 1);
        let mut d = Diagram::new(format!("gemmini{}x{}", cfg.dim, cfg.dim));
        let (_imem, ifs) = d.add_fetch(
            "instructionMemory",
            1,
            cfg.imem_port_width,
            "reorderBuffer",
            1,
            cfg.issue_buffer,
        );

        // DRAM with the linear burst model over (volume, start address)
        let read_expr = format!(
            "{base} + cdiv(imm0, {beat}) + (imm1 % {row}) / {beat}",
            base = cfg.dram_base_latency,
            beat = cfg.dram_words_per_beat,
            row = cfg.dram_row_words,
        );
        let dram = d.add_memory(
            "dram0",
            Latency::Expr(crate::acadl::Expr::parse(&read_expr)?),
            Latency::Expr(crate::acadl::Expr::parse(&read_expr)?),
            1,
            1,
            DRAM_BASE,
            REGION_WORDS,
        );
        // banked scratchpad + accumulator: token latency 1, two banks each
        let spad = d.add_memory("scratchpad", 1, 1, 1, 2, SPAD_BASE, REGION_WORDS);
        let acc = d.add_memory("accumulator", 1, 1, 1, 2, ACC_BASE, REGION_WORDS);

        let (state_rf, state_regs) = d.add_regfile("arrayState", "st", 2);
        let b_tile_reg = state_regs[0];
        let cfg_reg = state_regs[1];

        // decoupled access-execute: DMA engine stage
        let dma_es = d.add_execute_stage("dma_engine0");
        let mvin_fu = d.add_fu(dma_es, "mvinUnit", Latency::Fixed(1), &["mvin", "mvin_acc"]);
        let mvout_fu = d.add_fu(dma_es, "mvoutUnit", Latency::Fixed(1), &["mvout"]);
        d.forward(ifs, dma_es);

        // array stage
        let arr_es = d.add_execute_stage("gemmini0");
        let preload_fu = d.add_fu(
            arr_es,
            "preloadUnit",
            Latency::Fixed(Self::preload_cycles(cfg.dim)),
            &["preload"],
        );
        let compute_fu = d.add_fu(
            arr_es,
            "computeUnit",
            Latency::Fixed(Self::compute_cycles(cfg.dim)),
            &["compute_preloaded", "compute_accumulated"],
        );
        let config_fu = d.add_fu(
            arr_es,
            "configUnit",
            Latency::Fixed(2),
            &["config_ex", "config_ld", "config_st"],
        );
        d.forward(ifs, arr_es);

        // associations
        d.mem_reads(mvin_fu, dram);
        d.mem_writes(mvin_fu, spad);
        d.mem_writes(mvin_fu, acc); // mvin_acc targets the accumulator
        d.fu_reads(mvin_fu, state_rf); // config dependency
        d.mem_reads(mvout_fu, acc);
        d.mem_writes(mvout_fu, dram);
        d.fu_reads(mvout_fu, state_rf);

        d.mem_reads(preload_fu, spad);
        d.fu_writes(preload_fu, state_rf);
        d.fu_reads(preload_fu, state_rf);
        d.mem_reads(compute_fu, spad);
        d.mem_reads(compute_fu, acc);
        d.mem_writes(compute_fu, acc);
        d.fu_reads(compute_fu, state_rf);
        d.fu_writes(config_fu, state_rf);
        d.fu_reads(config_fu, state_rf);

        let ops = GemminiOps {
            config_ex: d.op("config_ex"),
            config_ld: d.op("config_ld"),
            config_st: d.op("config_st"),
            mvin: d.op("mvin"),
            mvin_acc: d.op("mvin_acc"),
            mvout: d.op("mvout"),
            preload: d.op("preload"),
            compute_preloaded: d.op("compute_preloaded"),
            compute_accumulated: d.op("compute_accumulated"),
        };
        d.finalize()?;
        Ok(Self { diagram: d, cfg, ops, dram, spad, acc, b_tile_reg, cfg_reg })
    }

    /// Bind a description-compiled diagram (see [`crate::acadl::text`]) to
    /// the tiled-GEMM-mapper handles, resolving ops, the three memories
    /// (`dram0`, `scratchpad`, `accumulator`), and the array-state
    /// registers (`st0` = B tile, `st1` = config) by name — see
    /// `arch/gemmini_16.toml`.
    pub fn from_described(diagram: Diagram, cfg: GemminiConfig) -> Result<Self> {
        anyhow::ensure!(cfg.dim >= 1, "dim must be >= 1");
        let what = "described gemmini diagram";
        let op = |name: &str| diagram.require_op(name, what);
        let ops = GemminiOps {
            config_ex: op("config_ex")?,
            config_ld: op("config_ld")?,
            config_st: op("config_st")?,
            mvin: op("mvin")?,
            mvin_acc: op("mvin_acc")?,
            mvout: op("mvout")?,
            preload: op("preload")?,
            compute_preloaded: op("compute_preloaded")?,
            compute_accumulated: op("compute_accumulated")?,
        };
        let mem = |name: &str| diagram.require_memory(name, what);
        let (dram, spad, acc) = (mem("dram0")?, mem("scratchpad")?, mem("accumulator")?);
        let (b_tile_reg, cfg_reg) =
            (diagram.require_reg("st0", what)?, diagram.require_reg("st1", what)?);
        Ok(Self { diagram, cfg, ops, dram, spad, acc, b_tile_reg, cfg_reg })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instruction;

    fn g() -> Gemmini {
        Gemmini::new(GemminiConfig::default()).unwrap()
    }

    #[test]
    fn dram_burst_model() {
        let cfg = GemminiConfig::default();
        // 256 words from aligned start: 12 + 32 + 0
        assert_eq!(Gemmini::dram_read_cycles(&cfg, 256, 0), 44);
        // unaligned start pays the row-open term
        assert!(Gemmini::dram_read_cycles(&cfg, 256, 128) > 44);
        // volume dominates asymptotically
        assert!(Gemmini::dram_read_cycles(&cfg, 4096, 0) > Gemmini::dram_read_cycles(&cfg, 256, 0));
    }

    #[test]
    fn dram_expr_matches_mirror() {
        let g = g();
        let i = Instruction::new(g.ops.mvin)
            .imms(&[256, 128])
            .read_mem(&[DRAM_BASE + 17])
            .write_mem(&[SPAD_BASE + 3]);
        let lat = g.diagram.mem_latency(g.dram, 1, false, &i);
        assert_eq!(lat, Gemmini::dram_read_cycles(&g.cfg, 256, 128));
    }

    #[test]
    fn mvin_routes_to_dma() {
        let g = g();
        let i = Instruction::new(g.ops.mvin)
            .imms(&[256, 0])
            .read_mem(&[DRAM_BASE])
            .write_mem(&[SPAD_BASE]);
        let r = g.diagram.route(&i).unwrap();
        assert_eq!(g.diagram.object(r.fu).name, "mvinUnit");
        assert!(r.has_writeback);
    }

    #[test]
    fn compute_routes_to_array() {
        let g = g();
        let i = Instruction::new(g.ops.compute_accumulated)
            .reads(&[g.b_tile_reg])
            .read_mem(&[SPAD_BASE])
            .write_mem(&[ACC_BASE]);
        let r = g.diagram.route(&i).unwrap();
        assert_eq!(g.diagram.object(r.fu).name, "computeUnit");
    }

    #[test]
    fn dma_and_array_have_separate_locks() {
        // the decoupled access-execute property: mvin and compute can
        // overlap, mvin and mvout cannot
        let g = g();
        let mvin = Instruction::new(g.ops.mvin)
            .imms(&[1, 0])
            .read_mem(&[DRAM_BASE])
            .write_mem(&[SPAD_BASE]);
        let mvout = Instruction::new(g.ops.mvout)
            .imms(&[1, 0])
            .read_mem(&[ACC_BASE])
            .write_mem(&[DRAM_BASE + 1]);
        let comp = Instruction::new(g.ops.compute_preloaded)
            .reads(&[g.b_tile_reg])
            .read_mem(&[SPAD_BASE])
            .write_mem(&[ACC_BASE]);
        let (ri, ro, rc) = (
            g.diagram.route(&mvin).unwrap(),
            g.diagram.route(&mvout).unwrap(),
            g.diagram.route(&comp).unwrap(),
        );
        assert_eq!(g.diagram.lock(ri.fu).owner, g.diagram.lock(ro.fu).owner);
        assert_ne!(g.diagram.lock(ri.fu).owner, g.diagram.lock(rc.fu).owner);
    }

    #[test]
    fn preload_feeds_compute_via_register() {
        let g = g();
        let preload = Instruction::new(g.ops.preload)
            .writes(&[g.b_tile_reg])
            .read_mem(&[SPAD_BASE + 1]);
        let r = g.diagram.route(&preload).unwrap();
        assert_eq!(g.diagram.object(r.fu).name, "preloadUnit");
    }
}
