//! UltraTrail — ultra-low-power 1D accelerator modeled at the fused-tensor
//! level (paper §4.3, Figs. 5/6; evaluated in §7.1).
//!
//! The 8×8 combinational MAC array *and* the output processing unit (OPU:
//! bias add, ReLU/clip, average pooling) are a **single FunctionalUnit**
//! (`macArrayAndOPU`) whose latency is the CONV-EXT analytical model of [4],
//! evaluated against each `conv_ext` instruction's immediates — the paper's
//! showcase of latency *expressions* spanning abstraction levels.
//!
//! CONV-EXT immediates (mapper contract, [`crate::mapping::tensor_op`]):
//!
//! | imm | meaning                                  |
//! |-----|------------------------------------------|
//! | 0   | C — input channels                       |
//! | 1   | C_w — input channel width                |
//! | 2   | K — output channels                      |
//! | 3   | F — filter width                         |
//! | 4   | S — stride                               |
//! | 5   | P — padding enabled (0/1)                |
//! | 6   | C_w^out — output width (precomputed)     |
//!
//! Analytical model (per [4], an N×N array computes N input × N output
//! channels per tap and cycle, the OPU pipes outputs through afterwards):
//!
//! ```text
//! t_conv_ext = ⌈C/N⌉·⌈K/N⌉·F·C_w^out + C_w^out + N
//! ```
//!
//! Memories follow Fig. 5: ping-pong feature memories FMEM0/FMEM1, FMEM2 for
//! residual operands, WMEM (weights), BMEM (bias), LMEM (partial sums, local
//! to the array). Their streaming time is *inside* the analytical model, so
//! the memory objects carry token latencies (1 cycle) — they exist to give
//! the AIDG the inter-layer data dependencies that serialize the layer
//! pipeline, exactly like the original model.

use anyhow::Result;

use crate::acadl::{Diagram, Latency};
use crate::ids::{Addr, ObjId, OpId};

/// FMEM0 base (layer inputs/outputs ping-pong between FMEM0/FMEM1).
pub const FMEM0_BASE: Addr = 0;
/// FMEM1 base (ping-pong partner of FMEM0).
pub const FMEM1_BASE: Addr = 1 << 20;
/// FMEM2: second operands of residual adds.
pub const FMEM2_BASE: Addr = 2 << 20;
/// Weight memory base.
pub const WMEM_BASE: Addr = 3 << 20;
/// Bias memory base.
pub const BMEM_BASE: Addr = 4 << 20;
/// Local memory base.
pub const LMEM_BASE: Addr = 5 << 20;
const MEM_WORDS: u64 = 1 << 20;

/// UltraTrail configuration (the shipped accelerator is 8×8).
#[derive(Debug, Clone, Copy)]
pub struct UltraTrailConfig {
    /// MAC array dimension N (N×N array, N in/out channels per cycle).
    pub array_dim: u32,
    /// Instruction memory port width.
    pub imem_port_width: u32,
    /// Issue buffer size of the fetch stage.
    pub issue_buffer: u32,
}

impl Default for UltraTrailConfig {
    fn default() -> Self {
        Self { array_dim: 8, imem_port_width: 1, issue_buffer: 2 }
    }
}

/// Interned UltraTrail tensor-ISA ops.
#[derive(Debug, Clone, Copy)]
pub struct UltraTrailOps {
    /// Fused conv + bias + activation + pooling (CONV-EXT).
    pub conv_ext: OpId,
    /// Fused fully-connected (+ activation): CONV-EXT with F=1, C_w=1.
    pub dense_ext: OpId,
    /// Element-wise residual addition on the MAC array.
    pub add_ext: OpId,
}

/// The instantiated UltraTrail model.
pub struct UltraTrail {
    /// The ACADL object diagram.
    pub diagram: Diagram,
    /// Instantiation configuration.
    pub cfg: UltraTrailConfig,
    /// Interned ISA handles.
    pub ops: UltraTrailOps,
    /// Feature memories FMEM0–2.
    pub fmem: [ObjId; 3],
    /// Weight memory.
    pub wmem: ObjId,
    /// Bias memory.
    pub bmem: ObjId,
    /// Local memory.
    pub lmem: ObjId,
}

impl UltraTrail {
    /// CONV-EXT analytical latency (the Latency::Expr evaluated per
    /// instruction; this mirror is used by tests and the roofline feature
    /// extraction).
    pub fn conv_ext_cycles(n: u32, c: u32, k: u32, f: u32, cw_out: u32) -> u64 {
        let n = n as u64;
        (c as u64).div_ceil(n) * (k as u64).div_ceil(n) * f as u64 * cw_out as u64
            + cw_out as u64
            + n
    }

    /// Element-wise add latency: ⌈C/N⌉ · C_w^out + N (one array row wave per
    /// channel tile).
    pub fn add_ext_cycles(n: u32, c: u32, cw_out: u32) -> u64 {
        (c as u64).div_ceil(n as u64) * cw_out as u64 + n as u64
    }

    /// Build the Fig. 6 ACADL object diagram.
    pub fn new(cfg: UltraTrailConfig) -> Result<Self> {
        assert!(cfg.array_dim >= 1);
        let n = cfg.array_dim;
        let mut d = Diagram::new(format!("ultratrail{n}x{n}"));
        let (_imem, ifs) = d.add_fetch(
            "instructionMemory",
            1,
            cfg.imem_port_width,
            "instructionFetchStage",
            1,
            cfg.issue_buffer,
        );

        let fmem0 = d.add_memory("fmem0", 1, 1, 8, 1, FMEM0_BASE, MEM_WORDS);
        let fmem1 = d.add_memory("fmem1", 1, 1, 8, 1, FMEM1_BASE, MEM_WORDS);
        let fmem2 = d.add_memory("fmem2", 1, 1, 8, 1, FMEM2_BASE, MEM_WORDS);
        let wmem = d.add_memory("wmem", 1, 1, 8, 1, WMEM_BASE, MEM_WORDS);
        let bmem = d.add_memory("bmem", 1, 1, 8, 1, BMEM_BASE, MEM_WORDS);
        let lmem = d.add_memory("lmem", 1, 1, 8, 1, LMEM_BASE, MEM_WORDS);

        // the MAC array's configuration register (written per layer by the
        // instruction stream, read by the array — models the layer config)
        let (cfg_rf, _cfg_regs) = d.add_regfile("configRegisters", "cfg", 1);

        let es = d.add_execute_stage("macArrayAndOPU.es");
        let conv_expr = format!(
            "cdiv(imm0, {n}) * cdiv(imm2, {n}) * imm3 * imm6 + imm6 + {n}"
        );
        let add_expr = format!("cdiv(imm0, {n}) * imm6 + {n}");
        let mac_fu = d.add_fu(
            es,
            "macArrayAndOPU",
            Latency::Expr(crate::acadl::Expr::parse(&conv_expr)?),
            &["conv_ext", "dense_ext"],
        );
        // element-wise adds run on the same array (sibling FU => shared
        // structural lock, exactly one tensor op in flight)
        let add_fu = d.add_fu(
            es,
            "macArrayOPU.addPath",
            Latency::Expr(crate::acadl::Expr::parse(&add_expr)?),
            &["add_ext"],
        );
        d.forward(ifs, es);

        for fu in [mac_fu, add_fu] {
            d.fu_reads(fu, cfg_rf);
            d.fu_writes(fu, cfg_rf);
            for m in [fmem0, fmem1, fmem2] {
                d.mem_reads(fu, m);
                d.mem_writes(fu, m);
            }
            d.mem_reads(fu, wmem);
            d.mem_reads(fu, bmem);
            d.mem_reads(fu, lmem);
            d.mem_writes(fu, lmem);
        }

        let ops = UltraTrailOps {
            conv_ext: d.op("conv_ext"),
            dense_ext: d.op("dense_ext"),
            add_ext: d.op("add_ext"),
        };
        d.finalize()?;
        Ok(Self { diagram: d, cfg, ops, fmem: [fmem0, fmem1, fmem2], wmem, bmem, lmem })
    }

    /// Bind a description-compiled diagram (see [`crate::acadl::text`]) to
    /// the tensor-op-mapper handles, resolving ops and memories by name
    /// (`fmem0`..`fmem2`, `wmem`, `bmem`, `lmem` — see
    /// `arch/ultratrail_8x8.toml`).
    pub fn from_described(diagram: Diagram, cfg: UltraTrailConfig) -> Result<Self> {
        anyhow::ensure!(cfg.array_dim >= 1, "array_dim must be >= 1");
        let what = "described ultratrail diagram";
        let ops = UltraTrailOps {
            conv_ext: diagram.require_op("conv_ext", what)?,
            dense_ext: diagram.require_op("dense_ext", what)?,
            add_ext: diagram.require_op("add_ext", what)?,
        };
        let mem = |name: &str| diagram.require_memory(name, what);
        let fmem = [mem("fmem0")?, mem("fmem1")?, mem("fmem2")?];
        let (wmem, bmem, lmem) = (mem("wmem")?, mem("bmem")?, mem("lmem")?);
        Ok(Self { diagram, cfg, ops, fmem, wmem, bmem, lmem })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instruction;

    #[test]
    fn builds_default() {
        let u = UltraTrail::new(UltraTrailConfig::default()).unwrap();
        assert_eq!(u.cfg.array_dim, 8);
        // imem, ifs, 6 memories, cfg rf, es, 2 FUs, writeBack
        assert!(u.diagram.num_objects() >= 12);
    }

    #[test]
    fn conv_ext_model_hand_calc() {
        // C=40, K=16, F=3, Cw_out=100 on 8x8: 5*2*3*100 + 100 + 8 = 3108
        assert_eq!(UltraTrail::conv_ext_cycles(8, 40, 16, 3, 100), 3108);
        // degenerate dense: C=48, K=12, F=1, out=1: 6*2*1*1 + 1 + 8 = 21
        assert_eq!(UltraTrail::conv_ext_cycles(8, 48, 12, 1, 1), 21);
    }

    #[test]
    fn conv_ext_latency_expr_matches_mirror() {
        let u = UltraTrail::new(UltraTrailConfig::default()).unwrap();
        let i = Instruction::new(u.ops.conv_ext)
            .imms(&[40, 100, 16, 3, 1, 1, 100])
            .read_mem(&[FMEM0_BASE, WMEM_BASE])
            .write_mem(&[FMEM1_BASE]);
        let route = u.diagram.route(&i).unwrap();
        let fu_obj = u.diagram.object(route.fu);
        if let crate::acadl::ObjectKind::FunctionalUnit { latency, .. } = &fu_obj.kind {
            assert_eq!(latency.eval(&i), UltraTrail::conv_ext_cycles(8, 40, 16, 3, 100));
        } else {
            panic!("route did not end at a functional unit");
        }
    }

    #[test]
    fn conv_ext_routes_to_mac_array() {
        let u = UltraTrail::new(UltraTrailConfig::default()).unwrap();
        let i = Instruction::new(u.ops.conv_ext)
            .imms(&[16, 50, 24, 9, 2, 1, 25])
            .read_mem(&[FMEM0_BASE + 4, WMEM_BASE + 9])
            .write_mem(&[FMEM1_BASE + 4]);
        let r = u.diagram.route(&i).unwrap();
        assert_eq!(u.diagram.object(r.fu).name, "macArrayAndOPU");
        assert_eq!(r.read_mems.len(), 2);
        assert!(r.has_writeback);
    }

    #[test]
    fn add_shares_structural_lock_with_conv() {
        let u = UltraTrail::new(UltraTrailConfig::default()).unwrap();
        let conv = Instruction::new(u.ops.conv_ext)
            .imms(&[8, 10, 8, 3, 1, 1, 10])
            .read_mem(&[FMEM0_BASE])
            .write_mem(&[FMEM1_BASE]);
        let add = Instruction::new(u.ops.add_ext)
            .imms(&[8, 10, 8, 0, 0, 0, 10])
            .read_mem(&[FMEM1_BASE, FMEM2_BASE])
            .write_mem(&[FMEM0_BASE]);
        let rc = u.diagram.route(&conv).unwrap();
        let ra = u.diagram.route(&add).unwrap();
        assert_ne!(rc.fu, ra.fu);
        assert_eq!(u.diagram.lock(rc.fu).owner, u.diagram.lock(ra.fu).owner);
    }

    #[test]
    fn bigger_array_is_faster() {
        let c8 = UltraTrail::conv_ext_cycles(8, 48, 48, 9, 13);
        let c16 = UltraTrail::conv_ext_cycles(16, 48, 48, 9, 13);
        assert!(c16 < c8);
    }
}
