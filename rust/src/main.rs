//! `acadl-perf` — CLI leader for the estimation service.
//!
//! ```text
//! acadl-perf estimate <arch> <network>             per-layer AIDG estimate
//! acadl-perf simulate <arch> <network>             cycle-accurate DES (slow)
//! acadl-perf compare <arch> <network>              AIDG vs roofline vs DES
//! acadl-perf dse --arch-file <path> --network-file <path>
//!               [--keep-frac F] [--sweep-cap N]    explore the file's [sweep]
//! acadl-perf dse <network> --rows R,.. --cols C,.. --tiles T,.. [--keep F]
//! acadl-perf dse plasticine:<R,..>x<C,..>:<T,..> <network> [--keep F]
//! acadl-perf check <file.toml>                     validate a description
//! acadl-perf calibrate [--out <path>] [--machines N] [--seed N]
//!                                                  train a DES-backed
//!                                                  calibration model
//! acadl-perf serve [--listen <addr>] [--max-clients N]
//!                  [--read-timeout-ms N] [--store <dir>]
//!                                                  line-based request loop:
//!                                                  stdio, or concurrent TCP
//!                                                  with --listen
//! acadl-perf store <stats|gc|flush> --store <dir>  offline store maintenance
//! acadl-perf info                                  platform + model zoo
//! ```
//!
//! Architecture specs: `systolic:4x4[:pw2]`, `ultratrail[:8]`,
//! `gemmini[:16]`, `plasticine:3x6:16`, or a textual ACADL description via
//! `file:<path>` / `--arch-file <path>` (see `arch/README.md`).
//!
//! Network specs: a zoo name (`tc_resnet8`, `alexnet`, ...), or a textual
//! network description via `net:<path>` / `--network-file <path>` (see
//! `net/README.md`). `check` accepts both description languages and picks
//! by content (a `[net]` section marks a network description).
//!
//! Global flags (anywhere on the command line):
//!
//! ```text
//! --workers <N>        worker threads for kernel-granular fan-out (0 = auto)
//! --cache-cap <N>      estimate-cache entry bound (0 disables caching)
//! --calib-file <path>  install a persisted calibration model: estimates
//!                      gain calibrated cycles + [ci_lo, ci_hi] error bars
//! --calibrate          train a calibration model in-process (seeded default
//!                      corpus) and install it for this run
//! --dispatch <mode>    AIDG dispatch: threaded (default, fused
//!                      superinstruction tape) or node-table (escape hatch)
//! --profile            enable tracing; print the span profile table at exit
//! --trace-out <path>   enable tracing; write Chrome trace JSON at exit
//! ```
//!
//! `--profile` and `--trace-out` turn the [`acadl_perf::obs`] tracing layer
//! on for the whole run; the trace file loads in Perfetto or
//! `chrome://tracing` (see `docs/observability.md`).

use anyhow::Context as _;

use acadl_perf::acadl::text::{check_source, Severity};
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{
    self, Arch, DescribedArch, DseSpec, EstimateRequest, Pool, RooflineBackend, ServeOptions,
};
use acadl_perf::dnn::text::check_net_source;
use acadl_perf::dse::{explore_space, SweepOptions, SweepSpace};
use acadl_perf::engine::EstimationEngine;
use acadl_perf::report::{fmt_bytes, fmt_cycles, Csv, Table};
use acadl_perf::Result;

/// Flags shared by every subcommand.
struct GlobalOpts {
    /// Worker threads (0 = available parallelism).
    workers: usize,
    /// Write the span ring as Chrome trace JSON here after the command.
    trace_out: Option<String>,
    /// Print the span profile table after the command.
    profile: bool,
}

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let run = match extract_global_flags(&mut args) {
        Ok(g) => dispatch(&args, &g).and_then(|()| finish_observability(&g)),
        Err(e) => Err(e),
    };
    if let Err(e) = run {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Post-dispatch telemetry surfaces: `--profile` prints the span profile
/// table, `--trace-out` writes the ring as Chrome trace-event JSON.
fn finish_observability(g: &GlobalOpts) -> Result<()> {
    if g.profile {
        print!("{}", acadl_perf::report::profile(&acadl_perf::obs::snapshot()).to_markdown());
    }
    if let Some(path) = &g.trace_out {
        let mut f = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("creating {path}: {e}"))?;
        acadl_perf::obs::write_chrome_trace(&mut f)
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        eprintln!("trace: wrote {path}");
    }
    Ok(())
}

/// Hard ceiling on `--workers`: more threads than this is always a typo,
/// and silently clamping would hide it.
const MAX_WORKERS: u64 = 4096;

/// Parse a non-negative count flag, rejecting non-numbers, overflow, and
/// values past `max` with messages that name the flag — never clamping.
fn parse_count_flag(flag: &str, value: &str, max: u64) -> Result<usize> {
    let v: u64 = value.parse().map_err(|_| {
        if !value.is_empty() && value.chars().all(|c| c.is_ascii_digit()) {
            anyhow::anyhow!("{flag} value {value:?} overflows (max {max})")
        } else {
            anyhow::anyhow!("{flag} value {value:?} is not a non-negative integer")
        }
    })?;
    anyhow::ensure!(v <= max, "{flag} value {v} is out of range (max {max})");
    usize::try_from(v).map_err(|_| anyhow::anyhow!("{flag} value {v} overflows usize"))
}

/// Parse a keep fraction, rejecting NaN/inf and anything outside 0..=1
/// with a proper error instead of silently clamping.
fn parse_keep_frac(flag: &str, value: &str) -> Result<f64> {
    let v: f64 = value
        .parse()
        .map_err(|_| anyhow::anyhow!("{flag} value {value:?} is not a number"))?;
    anyhow::ensure!(
        v.is_finite() && (0.0..=1.0).contains(&v),
        "{flag} must be a finite fraction in 0..=1 (got {value})"
    );
    Ok(v)
}

/// Strip the global flags (`--workers N`, `--cache-cap N`, `--dispatch
/// MODE`, `--trace-out PATH`, `--profile`) out of `args` — they are valid
/// in any position — applying the cache bound and dispatch mode to the
/// process-global defaults and enabling tracing when a telemetry flag is
/// present.
fn extract_global_flags(args: &mut Vec<String>) -> Result<GlobalOpts> {
    let mut opts = GlobalOpts { workers: 0, trace_out: None, profile: false };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--workers" => {
                anyhow::ensure!(i + 1 < args.len(), "--workers needs a value");
                opts.workers = parse_count_flag("--workers", &args[i + 1], MAX_WORKERS)?;
                args.drain(i..i + 2);
            }
            "--cache-cap" => {
                anyhow::ensure!(i + 1 < args.len(), "--cache-cap needs a value");
                let cap = parse_count_flag("--cache-cap", &args[i + 1], u64::MAX)?;
                EstimationEngine::global().set_cache_capacity(cap);
                args.drain(i..i + 2);
            }
            "--calib-file" => {
                anyhow::ensure!(i + 1 < args.len(), "--calib-file needs a path");
                let model =
                    acadl_perf::calib::CalibrationModel::load(std::path::Path::new(&args[i + 1]))?;
                EstimationEngine::global().set_calibration(Some(std::sync::Arc::new(model)));
                args.drain(i..i + 2);
            }
            "--calibrate" => {
                let (model, _) =
                    acadl_perf::calib::train_from_spec(&acadl_perf::calib::SampleSpec::default())?;
                EstimationEngine::global().set_calibration(Some(std::sync::Arc::new(model)));
                args.remove(i);
            }
            "--dispatch" => {
                anyhow::ensure!(i + 1 < args.len(), "--dispatch needs a mode");
                let mode = acadl_perf::aidg::DispatchMode::parse(&args[i + 1]).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--dispatch mode {:?} is not one of threaded | node-table",
                        args[i + 1]
                    )
                })?;
                acadl_perf::aidg::set_default_dispatch(mode);
                args.drain(i..i + 2);
            }
            "--trace-out" => {
                anyhow::ensure!(i + 1 < args.len(), "--trace-out needs a path");
                opts.trace_out = Some(args[i + 1].clone());
                acadl_perf::obs::set_enabled(true);
                args.drain(i..i + 2);
            }
            "--profile" => {
                opts.profile = true;
                acadl_perf::obs::set_enabled(true);
                args.remove(i);
            }
            _ => i += 1,
        }
    }
    Ok(opts)
}

fn dispatch(args: &[String], g: &GlobalOpts) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("estimate") => estimate(&args[1..], g),
        Some("simulate") => simulate(&args[1..]),
        Some("compare") => compare(&args[1..]),
        Some("dse") => dse(&args[1..], g),
        Some("check") => check(&args[1..]),
        Some("calibrate") => calibrate(&args[1..]),
        Some("serve") => serve_cmd(&args[1..], g),
        Some("store") => store_cmd(&args[1..]),
        Some("info") => info(),
        _ => {
            eprintln!("usage: acadl-perf <estimate|simulate|compare|dse|check|calibrate|serve|store|info> ...");
            eprintln!("  architectures: systolic:<R>x<C>[:pw<W>] | ultratrail[:N] | gemmini[:DIM] | plasticine:<R>x<C>:<T>");
            eprintln!("                 file:<path>  or  --arch-file <path>  (textual ACADL description)");
            eprintln!("  networks:      tc_resnet8 | alexnet | ... (acadl-perf info)");
            eprintln!("                 net:<path>  or  --network-file <path>  (textual network description)");
            eprintln!("  dse:           --arch-file <path> [--network-file <path>] [--keep-frac F] [--sweep-cap N] [--no-batch]");
            eprintln!("                 explores the description's [sweep] space (see docs/dse.md)");
            eprintln!("  calibrate:     [--out <path>] [--machines N] [--kernels N] [--seed N] [--kernel-seed N]");
            eprintln!("                 train an error-bar calibration model against the DES (docs/accuracy.md)");
            eprintln!("  serve:         [--listen <addr>] [--max-clients N] [--read-timeout-ms N] [--store <dir>]");
            eprintln!("                 stdio request loop by default; --listen starts the concurrent TCP front end");
            eprintln!("  store:         <stats|gc|flush> --store <dir>   offline persistent-store maintenance");
            eprintln!("  global flags:  --workers <N> (0 = auto) | --cache-cap <N> (estimate-cache entries)");
            eprintln!("                 --calib-file <path> (install a saved calibration model) | --calibrate");
            eprintln!("                 --dispatch <threaded|node-table> (AIDG evaluator dispatch; default threaded)");
            eprintln!("                 --profile (span profile table) | --trace-out <path> (Chrome trace JSON)");
            Ok(())
        }
    }
}

/// Parse the shared `<arch> <network>` argument grammar. `--arch-file` and
/// `--network-file` are accepted in any position; remaining positionals
/// fill the architecture spec first, then the network spec.
fn arch_and_net(args: &[String]) -> Result<(Arch, String)> {
    let mut arch: Option<Arch> = None;
    let mut network: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--arch-file" => {
                anyhow::ensure!(i + 1 < args.len(), "--arch-file needs a path");
                anyhow::ensure!(arch.is_none(), "architecture given twice");
                arch = Some(Arch::Described(DescribedArch::file(&args[i + 1])));
                i += 2;
            }
            "--network-file" => {
                anyhow::ensure!(i + 1 < args.len(), "--network-file needs a path");
                anyhow::ensure!(network.is_none(), "network given twice");
                network = Some(format!("net:{}", args[i + 1]));
                i += 2;
            }
            other => {
                if arch.is_none() {
                    arch = Some(coordinator::parse_arch(other)?);
                } else if network.is_none() {
                    network = Some(other.to_string());
                } else {
                    anyhow::bail!("unexpected argument {other:?}");
                }
                i += 1;
            }
        }
    }
    let arch = arch.ok_or_else(|| {
        anyhow::anyhow!("missing architecture (spec or --arch-file <path>)")
    })?;
    let network = network.ok_or_else(|| {
        anyhow::anyhow!("missing network (zoo name, net:<path>, or --network-file <path>)")
    })?;
    Ok((arch, network))
}

/// Grammar sniffing for `check`: a `[net]` section marks a network
/// description, and so do the network-only declarations — a net file that
/// *forgot* `[net]` still reaches the network validator's "missing [net]
/// section" error instead of confusing architecture-grammar diagnostics.
/// Headers are compared comment-stripped and whitespace-normalized, since
/// the lexer accepts `[net]  # comment` and `[[ layer ]]`. A file whose
/// *first* real section is the architecture-only `[sweep]` is an
/// architecture description no matter what later headers resemble.
fn sniff_is_network(src: &str) -> bool {
    let headers = src.lines().filter_map(|l| {
        let header: String =
            l.split('#').next().unwrap_or("").chars().filter(|c| !c.is_whitespace()).collect();
        header.starts_with('[').then_some(header)
    });
    let mut first_is_sweep = false;
    let mut has_net_marker = false;
    for (i, h) in headers.enumerate() {
        if i == 0 && h == "[sweep]" {
            first_is_sweep = true;
        }
        if matches!(h.as_str(), "[net]" | "[[layer]]" | "[[input]]" | "[[foreach]]") {
            has_net_marker = true;
        }
    }
    has_net_marker && !first_is_sweep
}

/// `acadl-perf check <file>`: parse + expand + validate a description and
/// print every diagnostic as `file:line:col: severity: message`. Both
/// description languages are accepted; a `[net]` section selects the
/// network grammar, anything else the architecture grammar.
fn check(args: &[String]) -> Result<()> {
    anyhow::ensure!(!args.is_empty(), "check <description.toml>");
    let path = &args[0];
    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let is_network = sniff_is_network(&src);
    let diags = if is_network {
        check_net_source(&src).1
    } else {
        check_source(&src).1
    };
    for d in &diags {
        println!("{}", d.render(path));
    }
    let errors = diags.iter().filter(|d| d.severity == Severity::Error).count();
    let warnings = diags.len() - errors;
    let what = if is_network { "network" } else { "architecture" };
    if errors > 0 {
        anyhow::bail!("{path}: {errors} error(s), {warnings} warning(s)");
    }
    println!("{path}: ok ({what} description, {warnings} warning(s))");
    Ok(())
}

/// `acadl-perf calibrate`: sample a seeded (machine × kernel) corpus, run
/// AIDG and DES on every pair, fit the stacked per-class correction, report
/// training accuracy, and optionally persist the model for `--calib-file`.
fn calibrate(args: &[String]) -> Result<()> {
    let mut out: Option<String> = None;
    let mut spec = acadl_perf::calib::SampleSpec::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--out" => {
                anyhow::ensure!(i + 1 < args.len(), "--out needs a path");
                out = Some(args[i + 1].clone());
                i += 2;
            }
            "--machines" => {
                anyhow::ensure!(i + 1 < args.len(), "--machines needs a value");
                spec.random_machines = parse_count_flag("--machines", &args[i + 1], 4096)?;
                i += 2;
            }
            "--kernels" => {
                anyhow::ensure!(i + 1 < args.len(), "--kernels needs a value");
                spec.kernels_per_machine = parse_count_flag("--kernels", &args[i + 1], 4096)?;
                i += 2;
            }
            "--seed" => {
                anyhow::ensure!(i + 1 < args.len(), "--seed needs a value");
                spec.machine_seed = args[i + 1]
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--seed value {:?} is not a u64", args[i + 1]))?;
                i += 2;
            }
            "--kernel-seed" => {
                anyhow::ensure!(i + 1 < args.len(), "--kernel-seed needs a value");
                spec.kernel_seed = args[i + 1].parse().map_err(|_| {
                    anyhow::anyhow!("--kernel-seed value {:?} is not a u64", args[i + 1])
                })?;
                i += 2;
            }
            other => anyhow::bail!("unknown calibrate flag {other:?}"),
        }
    }
    let (model, corpus) = acadl_perf::calib::train_from_spec(&spec)?;
    let acc = acadl_perf::calib::evaluate(&model, &corpus.samples);
    println!(
        "calibration: {} samples over {} machines -> {} exact classes",
        corpus.samples.len(),
        corpus.machines,
        model.class_count(),
    );
    println!(
        "training accuracy: raw MAPE {:.2}% -> calibrated MAPE {:.2}% | CI coverage {:.1}%",
        acc.raw_mape,
        acc.calibrated_mape,
        acc.ci_coverage * 100.0,
    );
    if let Some(path) = out {
        model.save(std::path::Path::new(&path))?;
        println!("saved: {path} (install with --calib-file {path} or `calibrate {path}` in serve)");
    }
    Ok(())
}

fn estimate(args: &[String], g: &GlobalOpts) -> Result<()> {
    let (arch, network) = arch_and_net(args)?;
    let pool = Pool::new(g.workers);
    let e = coordinator::run_request_pooled(
        &EstimateRequest { arch, network, fp: FixedPointConfig::default() },
        &pool,
    )?;
    let mut t = Table::new(
        format!("{} on {}", e.network, e.arch),
        &["layer", "cycles", "eval iters", "total iters", "fallback", "peak state"],
    );
    for l in &e.layers {
        match &l.estimate {
            None => t.row(&[
                l.layer_name.clone(),
                "fused".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]),
            Some(es) => t.row(&[
                l.layer_name.clone(),
                fmt_cycles(l.cycles()),
                l.evaluated_iters().to_string(),
                l.total_iters().to_string(),
                es.iter().any(|e| e.used_fallback).to_string(),
                fmt_bytes(l.peak_state_bytes()),
            ]),
        };
    }
    println!("{}", t.to_markdown());
    println!(
        "total: {} cycles | {} of {} iterations evaluated ({:.4}%) | {} instructions | {:.1} ms",
        fmt_cycles(e.total_cycles()),
        e.evaluated_iters(),
        e.total_iters(),
        100.0 * e.evaluated_iters() as f64 / e.total_iters().max(1) as f64,
        e.total_insts(),
        e.runtime.as_secs_f64() * 1e3,
    );
    if let Some(cal) = e.calibrated_cycles() {
        let (lo, hi) = e.ci_bounds().unwrap_or((cal, cal));
        println!(
            "calibrated: {} cycles | CI [{} – {}]",
            fmt_cycles(cal),
            fmt_cycles(lo),
            fmt_cycles(hi),
        );
    }
    println!(
        "engine: {} kernels ({} unique) | {} evaluated | {} cache hits | {} deduped | {} workers",
        e.stats.total_kernels,
        e.stats.unique_kernels,
        e.stats.evaluated,
        e.stats.cache_hits,
        e.stats.deduped,
        pool.workers(),
    );
    Ok(())
}

fn simulate(args: &[String]) -> Result<()> {
    let (arch, network) = arch_and_net(args)?;
    let net = coordinator::resolve_network(&network)?;
    let mapper = arch.mapper()?;
    let t0 = std::time::Instant::now();
    let mut total = 0u64;
    let mut insts = 0u64;
    for ml in mapper.map_network(&net)? {
        if ml.fused {
            continue;
        }
        let r = acadl_perf::sim::simulate_layer(mapper.diagram(), &ml.kernels)?;
        println!(
            "{:<28} {:>14} cycles  {:>12} instructions",
            ml.layer_name, r.cycles, r.instructions
        );
        total += r.cycles;
        insts += r.instructions;
    }
    println!(
        "total: {} cycles | {} instructions | {:.1} s wall",
        fmt_cycles(total),
        insts,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn compare(args: &[String]) -> Result<()> {
    let (arch, network) = arch_and_net(args)?;
    let net = coordinator::resolve_network(&network)?;
    let mapper = arch.mapper()?;

    // AIDG fixed-point estimate
    let t0 = std::time::Instant::now();
    let aidg =
        coordinator::estimate_network(mapper.as_ref(), &net, &FixedPointConfig::default())?;
    let aidg_rt = t0.elapsed();

    // refined roofline (native mirror; the XLA path is exercised in benches)
    let t1 = std::time::Instant::now();
    let mapped = mapper.map_network(&net)?;
    let roof =
        acadl_perf::baselines::roofline_network(&net.layers, &mapped, &mapper.hw_features());
    let roof_rt = t1.elapsed();

    // DES ground truth (executes everything — slow on big nets)
    let t2 = std::time::Instant::now();
    let mut des_total = 0u64;
    let mut des_layers = Vec::new();
    for ml in &mapped {
        if ml.fused {
            des_layers.push(0.0);
            continue;
        }
        let r = acadl_perf::sim::simulate_layer(mapper.diagram(), &ml.kernels)?;
        des_total += r.cycles;
        des_layers.push(r.cycles as f64);
    }
    let des_rt = t2.elapsed();

    let pe = |est: f64| acadl_perf::metrics::percentage_error(est, des_total as f64);
    let mut t = Table::new(
        format!("Estimator comparison — {} on {}", net.name, aidg.arch),
        &["estimator", "runtime", "estimated cycles", "PE", "MAPE"],
    );
    let aidg_cycles: Vec<f64> = aidg.layer_cycles();
    t.row(&[
        "AIDG fixed point".into(),
        format!("{:.1} ms", aidg_rt.as_secs_f64() * 1e3),
        fmt_cycles(aidg.total_cycles()),
        format!("{:.2}%", pe(aidg.total_cycles() as f64)),
        format!("{:.2}%", acadl_perf::metrics::mape(&des_layers, &aidg_cycles)),
    ]);
    // with a calibration model installed (--calibrate / --calib-file), add
    // the corrected estimate as its own comparison row
    if EstimationEngine::global().calibration().is_some() {
        let cal_est = EstimationEngine::global().estimate_network(
            &arch,
            &net,
            &FixedPointConfig::default(),
        )?;
        if let Some(cal_total) = cal_est.calibrated_cycles() {
            let cal_layers: Vec<f64> = cal_est
                .layers
                .iter()
                .map(|l| l.calibrated_cycles().unwrap_or(l.cycles()) as f64)
                .collect();
            t.row(&[
                "AIDG calibrated".into(),
                "-".into(),
                fmt_cycles(cal_total),
                format!("{:.2}%", pe(cal_total as f64)),
                format!("{:.2}%", acadl_perf::metrics::mape(&des_layers, &cal_layers)),
            ]);
        }
    }
    t.row(&[
        "Refined roofline [28]".into(),
        format!("{:.1} ms", roof_rt.as_secs_f64() * 1e3),
        fmt_cycles(roof.iter().sum::<f64>() as u64),
        format!("{:.2}%", pe(roof.iter().sum())),
        format!("{:.2}%", acadl_perf::metrics::mape(&des_layers, &roof)),
    ]);
    t.row(&[
        "Regression model [5]".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.2}%", acadl_perf::baselines::BOUZIDI_SVR_MAPE),
    ]);
    t.row(&[
        "DES (ground truth)".into(),
        format!("{:.2} s", des_rt.as_secs_f64()),
        fmt_cycles(des_total),
        "0.00%".into(),
        "0.00%".into(),
    ]);
    println!("{}", t.to_markdown());
    Ok(())
}

fn dse(args: &[String], g: &GlobalOpts) -> Result<()> {
    anyhow::ensure!(
        !args.is_empty(),
        "dse --arch-file <path> --network-file <path> [--keep-frac F] [--sweep-cap N] [--no-batch]\n\
         dse <network> --rows R,.. --cols C,.. --tiles T,.. [--keep F]"
    );
    if args.iter().any(|a| a == "--arch-file") {
        return dse_generic(args, g);
    }
    dse_plasticine(args, g)
}

/// Generic DSE over a described architecture's `[sweep]` space.
fn dse_generic(args: &[String], g: &GlobalOpts) -> Result<()> {
    let mut arch_file: Option<String> = None;
    let mut network: Option<String> = None;
    let mut keep = 1.0f64;
    let mut cap: Option<usize> = None;
    let mut batch = true;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--arch-file" => {
                anyhow::ensure!(i + 1 < args.len(), "--arch-file needs a path");
                anyhow::ensure!(arch_file.is_none(), "architecture given twice");
                arch_file = Some(args[i + 1].clone());
                i += 2;
            }
            "--network-file" => {
                anyhow::ensure!(i + 1 < args.len(), "--network-file needs a path");
                anyhow::ensure!(network.is_none(), "network given twice");
                network = Some(format!("net:{}", args[i + 1]));
                i += 2;
            }
            "--keep-frac" | "--keep" => {
                anyhow::ensure!(i + 1 < args.len(), "{} needs a value", args[i]);
                keep = parse_keep_frac(&args[i], &args[i + 1])?;
                i += 2;
            }
            "--sweep-cap" => {
                anyhow::ensure!(i + 1 < args.len(), "--sweep-cap needs a value");
                cap = Some(parse_count_flag("--sweep-cap", &args[i + 1], i64::MAX as u64)?);
                i += 2;
            }
            "--no-batch" => {
                // per-candidate accurate pass (bit-identical; for perf
                // comparison against the lane-batched dispatch)
                batch = false;
                i += 1;
            }
            other if !other.starts_with("--") && network.is_none() => {
                network = Some(other.to_string());
                i += 1;
            }
            other => anyhow::bail!("unknown dse flag {other:?}"),
        }
    }
    let arch_file = arch_file.context("missing --arch-file <path>")?;
    let network =
        network.context("missing network (zoo name, net:<path>, or --network-file <path>)")?;
    let src = std::fs::read_to_string(&arch_file)
        .map_err(|e| anyhow::anyhow!("reading {arch_file}: {e}"))?;
    let space = SweepSpace::from_source(&src, &arch_file, cap)?;
    let net = coordinator::resolve_network(&network)?;
    let pool = Pool::new(g.workers);
    let backend = RooflineBackend::auto();
    let opts = SweepOptions { keep_frac: keep, batch, ..Default::default() };
    let outcome =
        explore_space(&space, &net, &opts, &pool, &backend, EstimationEngine::global())?;

    let dims: Vec<String> = outcome
        .points
        .first()
        .map(|p| p.assignment.iter().map(|(n, _)| n.clone()).collect())
        .unwrap_or_default();
    let mut headers: Vec<&str> = vec!["arch"];
    headers.extend(dims.iter().map(String::as_str));
    headers.extend(["roofline cycles", "AIDG cycles", "PEs", "mem words", "frontier"]);
    let mut t = Table::new(
        format!(
            "DSE — {} × {} ({} points, {} estimated, {:.1} s)",
            arch_file,
            net.name,
            outcome.enumerated,
            outcome.estimated,
            outcome.wall.as_secs_f64()
        ),
        &headers,
    );
    let mut csv = Csv::new("dse_sweep", &headers);
    let mut omitted = 0usize;
    for p in &outcome.points {
        let mut cells = vec![p.arch_name.clone()];
        cells.extend(p.assignment.iter().map(|(_, v)| v.to_string()));
        cells.extend([
            fmt_cycles(p.roofline_cycles as u64),
            p.aidg_cycles.map(fmt_cycles).unwrap_or_else(|| "filtered".into()),
            p.pe_count.to_string(),
            p.mem_words.to_string(),
            if p.on_frontier { "*".into() } else { String::new() },
        ]);
        if outcome.points.len() <= 40 || p.on_frontier {
            t.row(&cells);
        } else {
            omitted += 1;
        }
        csv.row(&cells);
    }
    if omitted > 0 {
        let mut marker = vec![format!("… {omitted} non-frontier rows omitted (see CSV)")];
        marker.resize(headers.len(), String::new());
        t.row(&marker);
    }
    println!("{}", t.to_markdown());

    let mut f = Table::new(
        format!("Pareto frontier — cycles vs PE count vs memory ({} points)",
            outcome.frontier().len()),
        &["point", "arch", "AIDG cycles", "PEs", "mem words"],
    );
    for p in outcome.frontier() {
        f.row(&[
            p.label.clone(),
            p.arch_name.clone(),
            p.aidg_cycles.map(fmt_cycles).unwrap_or_default(),
            p.pe_count.to_string(),
            p.mem_words.to_string(),
        ]);
    }
    println!("{}", f.to_markdown());
    let csv_path = csv.finish()?;
    println!(
        "enumerated {} ({} skipped) | pre-filter kept {} | warm hit rate {:.1}% | \
         reuse {:.1}% | {:.1} points/s | series: {}",
        outcome.enumerated,
        outcome.skipped,
        outcome.estimated,
        outcome.warm_hit_rate() * 100.0,
        outcome.reuse_rate() * 100.0,
        outcome.enumerated as f64 / outcome.wall.as_secs_f64().max(1e-9),
        csv_path.display(),
    );
    Ok(())
}

/// Legacy Plasticine grid spellings:
/// `dse <network> --rows R,.. --cols C,.. --tiles T,.. [--keep F]` and
/// `dse plasticine:<R,..>x<C,..>:<T,..> <network> [--keep F]`.
fn dse_plasticine(args: &[String], g: &GlobalOpts) -> Result<()> {
    let mut rows = vec![2u32, 3, 4];
    let mut cols = vec![2u32, 4, 6];
    let mut tiles = vec![8u32, 16];
    let parse_list = |flag: &str, s: &str| -> Result<Vec<u32>> {
        let v: Vec<u32> = s
            .split(',')
            .map(|v| {
                v.parse()
                    .map_err(|_| anyhow::anyhow!("bad {flag} entry {v:?} in {s:?}"))
            })
            .collect::<Result<_>>()?;
        anyhow::ensure!(!v.is_empty(), "{flag} list is empty");
        Ok(v)
    };
    let mut network: Option<String> = None;
    let mut keep = 1.0f64;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--rows" | "--cols" | "--tiles" => {
                anyhow::ensure!(i + 1 < args.len(), "flag {} needs a value", args[i]);
                let list = parse_list(&args[i], &args[i + 1])?;
                match args[i].as_str() {
                    "--rows" => rows = list,
                    "--cols" => cols = list,
                    _ => tiles = list,
                }
                i += 2;
            }
            "--keep" | "--keep-frac" => {
                anyhow::ensure!(i + 1 < args.len(), "{} needs a value", args[i]);
                keep = parse_keep_frac(&args[i], &args[i + 1])?;
                i += 2;
            }
            spec if spec.starts_with("plasticine:") => {
                // the legacy arch spelling with comma lists per field
                let parts: Vec<&str> = spec.splitn(3, ':').collect();
                anyhow::ensure!(
                    parts.len() == 3,
                    "plasticine sweep spec needs <rows>x<cols>:<tiles> (got {spec:?})"
                );
                let (r, c) = parts[1]
                    .split_once('x')
                    .context("plasticine sweep spec needs <rows>x<cols>")?;
                rows = parse_list("rows", r)?;
                cols = parse_list("cols", c)?;
                tiles = parse_list("tiles", parts[2])?;
                i += 1;
            }
            other if !other.starts_with("--") && network.is_none() => {
                network = Some(other.to_string());
                i += 1;
            }
            other => anyhow::bail!("unknown dse flag {other:?}"),
        }
    }
    let network = network.context("dse <network> --rows R,.. --cols C,.. --tiles T,..")?;
    let spec =
        DseSpec { rows, cols, tiles, network, keep_frac: keep, fp: FixedPointConfig::default() };
    let pool = Pool::new(g.workers);
    let backend = RooflineBackend::auto();
    let t0 = std::time::Instant::now();
    let points = coordinator::explore(&spec, &pool, &backend)?;
    let mut t = Table::new(
        format!(
            "DSE — {} ({} design points, {:.1} s)",
            spec.network,
            points.len(),
            t0.elapsed().as_secs_f64()
        ),
        &["rows", "cols", "tile", "roofline cycles", "AIDG cycles"],
    );
    for p in points.iter().take(20) {
        t.row(&[
            p.rows.to_string(),
            p.cols.to_string(),
            p.tile.to_string(),
            fmt_cycles(p.roofline_cycles as u64),
            p.aidg_cycles.map(fmt_cycles).unwrap_or_else(|| "filtered".into()),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

/// `acadl-perf serve [--listen <addr>] [--max-clients N]
/// [--read-timeout-ms N] [--store <dir>]`: the stdio request loop by
/// default, or the concurrent TCP front end with `--listen` (port 0 picks
/// a free port; the resolved address is printed to stderr as
/// `serving on <addr>`).
fn serve_cmd(args: &[String], g: &GlobalOpts) -> Result<()> {
    let mut opts = ServeOptions { workers: g.workers, ..Default::default() };
    let mut listen: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--listen" => {
                anyhow::ensure!(i + 1 < args.len(), "--listen needs an address (host:port)");
                listen = Some(args[i + 1].clone());
                i += 2;
            }
            "--max-clients" => {
                anyhow::ensure!(i + 1 < args.len(), "--max-clients needs a value");
                opts.max_clients = parse_count_flag("--max-clients", &args[i + 1], 100_000)?;
                i += 2;
            }
            "--read-timeout-ms" => {
                anyhow::ensure!(i + 1 < args.len(), "--read-timeout-ms needs a value");
                let ms =
                    parse_count_flag("--read-timeout-ms", &args[i + 1], u64::from(u32::MAX))?;
                opts.read_timeout =
                    (ms > 0).then(|| std::time::Duration::from_millis(ms as u64));
                i += 2;
            }
            "--store" => {
                anyhow::ensure!(i + 1 < args.len(), "--store needs a directory");
                opts.store = Some(std::path::PathBuf::from(&args[i + 1]));
                i += 2;
            }
            other => anyhow::bail!("unknown serve flag {other:?}"),
        }
    }
    match listen {
        Some(addr) => {
            let srv = coordinator::NetServer::bind(&addr, opts)?;
            eprintln!("serving on {}", srv.local_addr());
            let out = srv.run()?;
            eprintln!("served {} sessions ({} requests)", out.sessions, out.requests);
            Ok(())
        }
        None => {
            let stdin = std::io::stdin();
            let n = coordinator::serve_with(stdin.lock(), std::io::stdout(), &opts)?;
            eprintln!("served {n} requests");
            Ok(())
        }
    }
}

/// `acadl-perf store <stats|gc|flush> --store <dir>`: inspect or maintain
/// a persistent estimate store without starting a server.
fn store_cmd(args: &[String]) -> Result<()> {
    anyhow::ensure!(!args.is_empty(), "store <stats|gc|flush> --store <dir>");
    let sub = args[0].as_str();
    let mut dir: Option<String> = None;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--store" => {
                anyhow::ensure!(i + 1 < args.len(), "--store needs a directory");
                dir = Some(args[i + 1].clone());
                i += 2;
            }
            other => anyhow::bail!("unknown store flag {other:?}"),
        }
    }
    let dir = dir.context("store needs --store <dir>")?;
    let store = acadl_perf::engine::EstimateStore::open(std::path::Path::new(&dir))?;
    match sub {
        "stats" => {
            let s = store.stats();
            println!(
                "store dir={} entries={} frontiers={} dirty={} segments={} gen={}",
                store.dir().display(),
                s.entries,
                s.frontiers,
                s.dirty,
                s.segments,
                s.open_gen,
            );
        }
        "gc" => {
            let o = store.gc()?;
            println!("store gc kept={} dropped={}", o.kept, o.dropped);
        }
        "flush" => {
            let n = store.flush()?;
            println!("store flushed records={n}");
        }
        other => anyhow::bail!("unknown store subcommand {other:?} (stats|gc|flush)"),
    }
    Ok(())
}

fn info() -> Result<()> {
    println!("acadl-perf — ACADL + AIDG performance-model generator");
    match acadl_perf::runtime::platform_info() {
        Ok(p) => println!("PJRT: {p}"),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    println!(
        "artifacts: {} ({})",
        acadl_perf::runtime::artifacts_dir().display(),
        if acadl_perf::runtime::artifacts_dir().join("roofline.hlo.txt").exists() {
            "built"
        } else {
            "missing — run `make artifacts`"
        }
    );
    println!(
        "networks: {} | net:<path> (textual description, see net/)",
        acadl_perf::dnn::zoo::all_names().join(", ")
    );
    println!("architectures: systolic:<R>x<C>[:pw<W>] | ultratrail[:N] | gemmini[:DIM] | plasticine:<R>x<C>:<T> | file:<path>");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_flags_reject_garbage_overflow_and_out_of_range() {
        assert_eq!(parse_count_flag("--workers", "8", MAX_WORKERS).unwrap(), 8);
        assert_eq!(parse_count_flag("--workers", "0", MAX_WORKERS).unwrap(), 0);
        let e = parse_count_flag("--workers", "4097", MAX_WORKERS).unwrap_err();
        assert!(format!("{e}").contains("out of range"), "{e}");
        let e = parse_count_flag("--workers", "99999999999999999999", MAX_WORKERS).unwrap_err();
        assert!(format!("{e}").contains("overflows"), "{e}");
        let e = parse_count_flag("--cache-cap", "-3", u64::MAX).unwrap_err();
        assert!(format!("{e}").contains("not a non-negative integer"), "{e}");
        assert!(parse_count_flag("--cache-cap", "twelve", u64::MAX).is_err());
        assert!(parse_count_flag("--cache-cap", "", u64::MAX).is_err());
    }

    #[test]
    fn keep_frac_rejects_nan_and_out_of_range() {
        assert_eq!(parse_keep_frac("--keep-frac", "0.5").unwrap(), 0.5);
        assert_eq!(parse_keep_frac("--keep-frac", "1").unwrap(), 1.0);
        assert_eq!(parse_keep_frac("--keep-frac", "0").unwrap(), 0.0);
        for bad in ["NaN", "nan", "inf", "-0.1", "1.01", "two"] {
            let e = parse_keep_frac("--keep", bad).unwrap_err();
            let msg = format!("{e}");
            assert!(msg.contains("--keep"), "{bad}: {msg}");
        }
    }

    #[test]
    fn extract_global_flags_strips_and_validates() {
        let mut args: Vec<String> =
            ["estimate", "--workers", "3", "ultratrail", "tc_resnet8"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let g = extract_global_flags(&mut args).unwrap();
        assert_eq!(g.workers, 3);
        assert!(g.trace_out.is_none());
        assert!(!g.profile);
        assert_eq!(args, vec!["estimate", "ultratrail", "tc_resnet8"]);
        let mut bad: Vec<String> =
            ["--workers", "1000000"].iter().map(|s| s.to_string()).collect();
        assert!(extract_global_flags(&mut bad).is_err());
    }

    #[test]
    fn dispatch_flag_sets_the_process_default() {
        use acadl_perf::aidg::{default_dispatch, set_default_dispatch, DispatchMode};
        let mut args: Vec<String> =
            ["estimate", "--dispatch", "node-table", "ultratrail", "tc_resnet8"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        extract_global_flags(&mut args).unwrap();
        assert_eq!(args, vec!["estimate", "ultratrail", "tc_resnet8"]);
        assert_eq!(default_dispatch(), DispatchMode::NodeTable);
        // restore: the default is process-global
        set_default_dispatch(DispatchMode::Threaded);

        let mut bad: Vec<String> =
            ["--dispatch", "goto"].iter().map(|s| s.to_string()).collect();
        let e = extract_global_flags(&mut bad).unwrap_err();
        assert!(format!("{e}").contains("--dispatch"));
        let mut missing: Vec<String> = ["--dispatch"].iter().map(|s| s.to_string()).collect();
        assert!(extract_global_flags(&mut missing).is_err());
    }

    #[test]
    fn telemetry_flags_strip_and_enable_tracing() {
        let mut args: Vec<String> =
            ["estimate", "--profile", "gemmini", "--trace-out", "t.json", "tc_resnet8"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let g = extract_global_flags(&mut args).unwrap();
        assert!(g.profile);
        assert_eq!(g.trace_out.as_deref(), Some("t.json"));
        assert_eq!(args, vec!["estimate", "gemmini", "tc_resnet8"]);
        assert!(acadl_perf::obs::enabled());
        let mut bad: Vec<String> = ["--trace-out"].iter().map(|s| s.to_string()).collect();
        assert!(extract_global_flags(&mut bad).is_err());
    }

    #[test]
    fn sniffing_picks_the_right_grammar() {
        assert!(sniff_is_network("[net]\nname = \"x\"\n"));
        assert!(sniff_is_network("# c\n[[layer]]\nname = \"x\"\n"));
        assert!(!sniff_is_network("[arch]\nname = \"x\"\n[sweep]\nrows = 1\n"));
        // first real section [sweep] => architecture, even with net-like
        // headers further down (e.g. in a commented-out example... or not)
        assert!(!sniff_is_network("# preamble\n[sweep]  # space\nrows = 1\n[net]\n"));
        assert!(sniff_is_network("[net]\n[sweep]\n"));
        assert!(!sniff_is_network("x = 1\n"));
    }
}
