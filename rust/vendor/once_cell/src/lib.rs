//! Minimal, API-compatible stand-in for the `once_cell` crate.
//!
//! The container image this repo builds in has no crates.io registry, so the
//! two types the codebase uses are vendored here: [`sync::Lazy`] (built on
//! `std::sync::OnceLock`) and [`unsync::OnceCell`] (single-threaded, with
//! `get_or_try_init`, which is still unstable on `std::cell::OnceCell`).

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access. For `static` use the init
    /// closure must be capture-less (it coerces to the `fn() -> T` default).
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Self {
            Self { cell: OnceLock::new(), init }
        }
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        /// Force evaluation and return a reference to the value.
        pub fn force(this: &Self) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

pub mod unsync {
    use std::cell::UnsafeCell;

    /// A single-threaded write-once cell.
    ///
    /// Safety model: `!Sync` (via `UnsafeCell`), and the value slot is only
    /// written while no `&T` has ever been handed out (it transitions
    /// `None -> Some` exactly once and is never overwritten), so returned
    /// references stay valid for the cell's lifetime. The init closure must
    /// not reentrantly initialize the same cell.
    pub struct OnceCell<T> {
        value: UnsafeCell<Option<T>>,
    }

    impl<T> OnceCell<T> {
        pub const fn new() -> Self {
            Self { value: UnsafeCell::new(None) }
        }

        pub fn get(&self) -> Option<&T> {
            // SAFETY: !Sync; the slot is never overwritten once Some.
            unsafe { (*self.value.get()).as_ref() }
        }

        /// Set the value; errors with it if already initialized.
        pub fn set(&self, value: T) -> Result<(), T> {
            if self.get().is_some() {
                return Err(value);
            }
            // SAFETY: slot is None, no outstanding &T can exist.
            unsafe { *self.value.get() = Some(value) };
            Ok(())
        }

        pub fn get_or_init(&self, f: impl FnOnce() -> T) -> &T {
            match self.get_or_try_init(|| Ok::<T, std::convert::Infallible>(f())) {
                Ok(v) => v,
                Err(never) => match never {},
            }
        }

        pub fn get_or_try_init<E>(&self, f: impl FnOnce() -> Result<T, E>) -> Result<&T, E> {
            if let Some(v) = self.get() {
                return Ok(v);
            }
            let value = f()?;
            // SAFETY: still single-threaded; f() must not have initialized
            // the cell reentrantly (per the type's contract).
            unsafe { *self.value.get() = Some(value) };
            Ok(self.get().expect("just initialized"))
        }
    }

    impl<T> Default for OnceCell<T> {
        fn default() -> Self {
            Self::new()
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn lazy_static_initializes_once() {
        static N: super::sync::Lazy<u32> = super::sync::Lazy::new(|| 41 + 1);
        assert_eq!(*N, 42);
        assert_eq!(*N, 42);
    }

    #[test]
    fn unsync_once_cell() {
        let c = super::unsync::OnceCell::new();
        assert!(c.get().is_none());
        assert_eq!(c.get_or_try_init(|| Ok::<_, ()>(7)).unwrap(), &7);
        assert_eq!(c.get(), Some(&7));
        assert!(c.set(9).is_err());
        assert_eq!(c.get_or_init(|| 11), &7);
    }
}
