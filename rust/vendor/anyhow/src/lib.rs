//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! The container image this repo builds in has no crates.io registry, so the
//! subset of `anyhow` the codebase actually uses is vendored here: [`Error`]
//! (a boxed context chain), [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream where it matters to callers:
//! - `Display` shows the outermost message only;
//! - `{:#}` (alternate) shows the whole chain joined with `": "`;
//! - `Debug` shows the outermost message plus a `Caused by:` list;
//! - `Error` deliberately does **not** implement `std::error::Error`, so the
//!   blanket `From<E: std::error::Error>` conversion can coexist with the
//!   reflexive `From<Error>`.

use std::fmt::{self, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus the chain of causes.
pub struct Error {
    /// `chain[0]` is the outermost context, `chain.last()` the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(message: impl Display) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap this error in an additional layer of context.
    pub fn context(mut self, context: impl Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The chain of messages, outermost first (mirror of upstream
    /// `Error::chain`, as strings).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Attach a lazily-evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// Coherent with the impl above because `Error` is a local type that is
// guaranteed (orphan rules) never to implement `std::error::Error`.
impl<T> Context<T> for Result<T, Error> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: `", stringify!($cond), "`")));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_u32(s: &str) -> Result<u32> {
        let v = s.parse::<u32>().context("parsing a u32")?;
        Ok(v)
    }

    #[test]
    fn context_chain_formats() {
        let e = parse_u32("x").unwrap_err();
        assert_eq!(e.to_string(), "parsing a u32");
        let full = format!("{e:#}");
        assert!(full.starts_with("parsing a u32: "), "{full}");
        assert!(format!("{e:?}").contains("Caused by"), "{e:?}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(7).context("missing").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fell through with {}", x))
        }
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through with 1");
    }

    #[test]
    fn from_walks_source_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "inner");
        let e = Error::from(io).context("outer");
        assert_eq!(e.root_cause(), "inner");
        assert_eq!(e.chain().count(), 2);
    }
}
